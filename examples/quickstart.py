"""Quickstart: the paper's RL-CFD loop through the env registry.

Any registered scenario — the paper's 3-D HIT-LES, the 1-D Burgers control
problem, or the wall-modeled channel flow (velocity-only or the 4-channel
velocity + wall-pressure variant) — trains through the same ~10 lines:

    from repro import envs
    from repro.core.orchestrator import FleetConfig
    from repro.core.runner import Runner, RunnerConfig

    env = envs.make("hit_les_reduced")   # or "burgers_reduced", "channel_wm"
    runner = Runner(env, FleetConfig(n_envs=4, bank_size=9))
    history = runner.train()

This script does exactly that for every scenario family at CPU smoke scale
(or one scenario of your choice via --env), then peeks under the hood: the
spec-built policy and one sharded fleet rollout.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --env channel_wm_reduced
    # (pytest needs no prefix: pyproject.toml sets pythonpath = ["src"])
"""
import argparse

import jax
import jax.numpy as jnp

from repro import envs
from repro.core import policy, rollout
from repro.core.orchestrator import FleetConfig
from repro.core.runner import Runner, RunnerConfig

SMOKE_SCENARIOS = ("hit_les_reduced", "burgers_reduced", "channel_wm_reduced",
                   "channel_wm_p_reduced")

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--env", default=None, choices=envs.registered(),
                help="train one registered scenario instead of the "
                     "reduced smoke set")
args = ap.parse_args()

print("registered environments:", ", ".join(envs.registered()))

# 1. Train every scenario family through the identical runner code path.
for name in ((args.env,) if args.env else SMOKE_SCENARIOS):
    env = envs.make(name)
    runner = Runner(
        env, FleetConfig(n_envs=2, bank_size=4),
        run_cfg=RunnerConfig(n_iterations=3, eval_every=2, checkpoint_every=10,
                             checkpoint_dir=f"checkpoints/quickstart_{name}",
                             async_checkpoint=False),
    )
    history = runner.train(resume=False)
    returns = [f"{r['return_norm']:+.3f}" for r in history]
    print(f"{name}: obs {env.obs_spec.shape} "
          f"[{','.join(env.obs_spec.channel_names)}] "
          f"act {env.action_spec.shape} "
          f"T={env.n_actions} -> returns {' '.join(returns)}")

# 2. Under the hood: the policy heads come from the env's declarative specs
#    (the paper's Table-2 Conv3D stack for HIT; the same plan in 1-D for
#    Burgers), and one episode of the whole fleet is ONE jitted scan — the
#    SmartSim launch/poll loop of the paper collapses into this call.
env = envs.make("hit_les_reduced")
pcfg = policy.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
params = policy.init(jax.random.PRNGKey(0), pcfg)
print(f"\npolicy parameters: {policy.param_count(params):,} "
      f"(paper-scale N=5 has 3,294 — tests/test_ppo.py pins Table 2)")
u0 = env.initial_state_bank(jax.random.PRNGKey(1), 4)
traj = jax.jit(lambda p, u, k: rollout.rollout(p, pcfg, env, u, k)
               )(params, u0, jax.random.PRNGKey(2))
print(f"sampled fleet: T={traj.rewards.shape[0]} steps x "
      f"B={traj.rewards.shape[1]} envs, "
      f"mean return={float(jnp.mean(jnp.sum(traj.rewards, 0))):.3f}")
print("(train longer with: python -m repro.launch.rl_train --env hit_les_24dof)")

"""Quickstart: the paper's RL-CFD loop in ~40 lines of public API.

Rolls a fleet of HIT LES environments with the Table-2 Conv3D policy,
runs one PPO update, and evaluates against the Smagorinsky baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import relexi_hit
from repro.core import policy, ppo, rollout
from repro.cfd import initial, spectra

# 1. Environment: CPU-scale homogeneous isotropic turbulence (the paper's
#    Table-1 configs are relexi_hit.HIT24 / HIT32).
env_cfg = relexi_hit.reduced()
e_dns = jnp.asarray(spectra.reference_spectrum(env_cfg), jnp.float32)

# 2. Policy: the paper's Table-2 Conv3D actor-critic (~3.3k parameters).
pcfg = policy.PolicyConfig(n_nodes=env_cfg.n_poly + 1, cs_max=env_cfg.cs_max)
params = policy.init(jax.random.PRNGKey(0), pcfg)
print(f"policy parameters: {policy.param_count(params):,} "
      f"(reduced N={env_cfg.n_poly}; the paper-scale N=5 policy has 3,294 — "
      f"see tests/test_ppo.py::test_policy_param_count_matches_table2)")

# 3. Sample a fleet of parallel environments (one sharded XLA program —
#    the SmartSim launch/poll loop of the paper collapses into this call).
u0 = initial.make_state_bank(jax.random.PRNGKey(1), env_cfg, 4)[:4]
traj = jax.jit(lambda p, u, k: rollout.rollout(p, pcfg, env_cfg, e_dns, u, k)
               )(params, u0, jax.random.PRNGKey(2))
print(f"sampled fleet: T={traj.rewards.shape[0]} steps x "
      f"B={traj.rewards.shape[1]} envs, "
      f"mean return={float(jnp.mean(jnp.sum(traj.rewards, 0))):.3f}")

# 4. One PPO update (paper Sec. 5.3 hyperparameters).
ppo_cfg = ppo.PPOConfig()
opt_state = optim.adam_init(params)
params, opt_state, stats = jax.jit(
    lambda p, o, t: ppo.update(p, o, ppo_cfg, pcfg, t))(params, opt_state, traj)
print(f"PPO update: loss={float(stats['loss']):.4f} "
      f"clip_frac={float(stats['clip_frac']):.3f}")

# 5. Compare one episode of the (single-step-trained) policy with the
#    static Smagorinsky baseline on a fresh state.
traj2 = jax.jit(lambda p, u, k: rollout.rollout(p, pcfg, env_cfg, e_dns, u, k,
                                                deterministic=True)
                )(params, u0[:1], jax.random.PRNGKey(3))
print(f"deterministic episode return (RL, 1 update): "
      f"{float(rollout.normalized_return(traj2)[0]):.3f}")
print("(train longer with: python -m repro.launch.rl_train --reduced)")

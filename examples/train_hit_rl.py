"""End-to-end driver: train the paper's RL turbulence model (Fig. 5).

Runs the full fault-tolerant Relexi loop — fleet rollout, PPO update,
evaluation on the held-out state every 10 iterations, checkpoints — at
CPU scale (a few hundred gradient steps), then compares the trained
dynamic-C_s model against the paper's two baselines.

    PYTHONPATH=src python examples/train_hit_rl.py [--iterations 60]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import relexi_hit
from repro.core.orchestrator import FleetConfig
from repro.core.ppo import PPOConfig
from repro.core.runner import Runner, RunnerConfig
from repro.cfd import env as env_lib


def constant_cs_return(orch, cs_value: float) -> float:
    cfg = orch.env_cfg
    u0 = orch.test_state()
    state = env_lib.EnvState(u=u0, t_step=jnp.zeros((1,), jnp.int32))
    action = jnp.full((1, cfg.n_elem**3), cs_value, jnp.float32)
    step = jax.jit(lambda s, a: env_lib.step(s, a, cfg, orch.e_dns))
    total = 0.0
    for _ in range(cfg.n_actions):
        res = step(state, action)
        state = res.state
        total += float(res.reward[0])
    return total / cfg.n_actions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=60)
    ap.add_argument("--n-envs", type=int, default=4)
    ap.add_argument("--checkpoint-dir", default="checkpoints/example_rl")
    args = ap.parse_args()

    env_cfg = relexi_hit.reduced()
    runner = Runner(
        env_cfg,
        FleetConfig(n_envs=args.n_envs, bank_size=args.n_envs + 5),
        ppo_cfg=PPOConfig(),  # paper Sec. 5.3: gamma .995, lr 1e-4, 5 epochs
        run_cfg=RunnerConfig(n_iterations=args.iterations, eval_every=10,
                             checkpoint_every=20,
                             checkpoint_dir=args.checkpoint_dir),
    )
    print(f"training {args.iterations} iterations x {args.n_envs} envs ...")
    history = runner.train()
    first = next(r["return_norm"] for r in history if "return_norm" in r)
    last = history[-1].get("return_norm", float("nan"))
    print(f"\nreturn (normalized): first={first:.4f} last={last:.4f}")

    rl_eval = float(runner.orch.evaluate(runner.params))
    smag = constant_cs_return(runner.orch, 0.17)
    impl = constant_cs_return(runner.orch, 0.0)
    print("\n=== held-out test state (paper Fig. 5 bottom) ===")
    print(f"  RL dynamic C_s     : {rl_eval:.4f}")
    print(f"  Smagorinsky C_s=.17: {smag:.4f}")
    print(f"  implicit LES C_s=0 : {impl:.4f}")


if __name__ == "__main__":
    main()

"""End-to-end driver: train the paper's RL turbulence model (Fig. 5).

Runs the full fault-tolerant Relexi loop — fleet rollout, PPO update,
evaluation on the held-out state every 10 iterations, checkpoints — at
CPU scale (a few hundred gradient steps), then compares the trained
dynamic-C_s model against the paper's two baselines.

    PYTHONPATH=src python examples/train_hit_rl.py [--iterations 60]
"""
import argparse

from repro import envs
from repro.core.orchestrator import FleetConfig
from repro.core.ppo import PPOConfig
from repro.core.rollout import constant_action_return
from repro.core.runner import Runner, RunnerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="hit_les_reduced",
                    choices=envs.registered())
    ap.add_argument("--iterations", type=int, default=60)
    ap.add_argument("--n-envs", type=int, default=4)
    ap.add_argument("--checkpoint-dir", default="checkpoints/example_rl")
    args = ap.parse_args()

    runner = Runner(
        envs.make(args.env),
        FleetConfig(n_envs=args.n_envs, bank_size=args.n_envs + 5),
        ppo_cfg=PPOConfig(),  # paper Sec. 5.3: gamma .995, lr 1e-4, 5 epochs
        run_cfg=RunnerConfig(n_iterations=args.iterations, eval_every=10,
                             checkpoint_every=20,
                             checkpoint_dir=args.checkpoint_dir),
    )
    print(f"training {args.env}: {args.iterations} iterations x "
          f"{args.n_envs} envs ...")
    history = runner.train()
    first = next(r["return_norm"] for r in history if "return_norm" in r)
    last = history[-1].get("return_norm", float("nan"))
    print(f"\nreturn (normalized): first={first:.4f} last={last:.4f}")

    orch = runner.orch
    rl_eval = float(orch.evaluate(runner.params))
    smag = constant_action_return(orch.env, orch.test_state(), 0.17)
    impl = constant_action_return(orch.env, orch.test_state(), 0.0)
    print("\n=== held-out test state (paper Fig. 5 bottom) ===")
    print(f"  RL dynamic coefficient : {rl_eval:.4f}")
    print(f"  static C=0.17 baseline : {smag:.4f}")
    print(f"  implicit LES C=0       : {impl:.4f}")


if __name__ == "__main__":
    main()

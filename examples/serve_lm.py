"""Serve a small LM with batched requests: prefill + autoregressive decode.

Exercises the same prefill/serve_step programs the decode_32k dry-run cells
lower at production scale, on a reduced config of an assigned architecture.

    PYTHONPATH=src python examples/serve_lm.py --arch h2o-danube-1.8b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import make_batch_for
from repro.models import api

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="h2o-danube-1.8b",
                choices=configs.ARCH_NAMES)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=48)
ap.add_argument("--gen", type=int, default=24)
args = ap.parse_args()

cfg = configs.get_reduced(args.arch)
params = api.init(jax.random.PRNGKey(0), cfg)
batch = make_batch_for(cfg, 0, args.batch, args.prompt_len)
batch.pop("labels", None)

prefill = jax.jit(lambda p, b: api.prefill(p, cfg, b,
                                           cache_len=args.prompt_len + args.gen))
decode = jax.jit(lambda p, t, c: api.serve_step(p, cfg, t, c),
                 donate_argnums=(2,))

t0 = time.perf_counter()
logits, caches = jax.block_until_ready(prefill(params, batch))
print(f"prefill {args.batch}x{args.prompt_len} tokens: "
      f"{(time.perf_counter()-t0)*1e3:.0f} ms (incl. compile)")

tok = jnp.argmax(logits, -1).astype(jnp.int32)
out = [np.asarray(tok)]
t0 = time.perf_counter()
for _ in range(args.gen - 1):
    logits, caches = decode(params, tok, caches)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(np.asarray(tok))
jax.block_until_ready(tok)
dt = time.perf_counter() - t0
print(f"decoded {args.batch * args.gen} tokens in {dt*1e3:.0f} ms "
      f"({args.batch * args.gen / dt:,.0f} tok/s incl. compile)")
print("completions (token ids):")
for row in np.stack(out, 1)[:2]:
    print("  ", row[:16].tolist())

"""Serve trained eddy-viscosity controllers from the newest fleet checkpoint.

The serving half of the HPC story: training (`fleet/pipeline.py`) leaves a
checkpoint of the multitask policy tree; this example restores ONLY the
policy from it (`repro.serve.load_service`), then answers a batch of
observation requests for two scenarios through the bucket-compiled
dispatch layer — the deterministic greedy actions any solver would consume.

Self-contained: when the checkpoint directory is empty, a short reduced
fleet run is trained first to produce one.

    PYTHONPATH=src python examples/serve_controller.py
    PYTHONPATH=src python examples/serve_controller.py --requests 5 \
        --checkpoint-dir checkpoints/fleet
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import envs, fleet, serve
from repro.core import checkpoints
from repro.fleet.pipeline import FleetRunnerConfig

SCENARIOS = ("hit_les_reduced", "burgers_reduced")

ap = argparse.ArgumentParser()
ap.add_argument("--checkpoint-dir", default="checkpoints/serve_demo")
ap.add_argument("--requests", type=int, default=3,
                help="observation requests per scenario")
ap.add_argument("--train-iters", type=int, default=2,
                help="reduced training iterations when no checkpoint exists")
args = ap.parse_args()

if checkpoints.latest_step(args.checkpoint_dir) is None:
    print(f"no checkpoint under {args.checkpoint_dir!r} — training "
          f"{args.train_iters} reduced fleet iterations first")
    runner = fleet.make_fleet_runner(
        SCENARIOS, total_envs=4,
        run_cfg=FleetRunnerConfig(
            n_iterations=args.train_iters, eval_every=100,
            checkpoint_every=args.train_iters, async_checkpoint=False,
            checkpoint_dir=args.checkpoint_dir, bank_size=4),
        use_artifacts=False)
    runner.train(resume=False)

svc = serve.load_service(args.checkpoint_dir)
print(f"serving scenarios {svc.scenarios} from step "
      f"{checkpoints.latest_step(args.checkpoint_dir)}")

# real observations: reset each scenario's env from a fresh state bank and
# observe — exactly what a coupled solver would send over the wire
uids = {}
for name in svc.scenarios:
    env = envs.make(name)
    bank = env.initial_state_bank(jax.random.PRNGKey(0), args.requests + 1)
    for i in range(args.requests):
        _, obs = env.reset_from_bank(bank, jnp.asarray(i))
        uids[svc.submit(name, np.asarray(obs))] = name

t0 = time.perf_counter()
results = svc.flush()
dt = time.perf_counter() - t0

for uid, name in uids.items():
    res = results[uid]
    a = res.action
    print(f"  req {uid} [{name}] -> action[{a.shape[0]} elems] "
          f"mean={a.mean():.4f} min={a.min():.4f} max={a.max():.4f} "
          f"value={res.value:+.4f}")
print(f"answered {len(results)} requests in {dt * 1e3:.1f} ms "
      f"({len(results) / dt:,.0f} req/s, first-call compiles included)")
print(f"telemetry: {svc.stats()}")

"""Scaling demo (paper Sec. 6.1): weak-scale the environment fleet and show
the launch-overhead amortization of the single-program design.

    PYTHONPATH=src python examples/scaling_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import relexi_hit
from repro.core import policy, rollout
from repro.cfd import initial, spectra

env_cfg = relexi_hit.reduced()
pcfg = policy.PolicyConfig(n_nodes=env_cfg.n_poly + 1, cs_max=env_cfg.cs_max)
params = policy.init(jax.random.PRNGKey(0), pcfg)
e_dns = jnp.asarray(spectra.reference_spectrum(env_cfg), jnp.float32)
bank = initial.make_state_bank(jax.random.PRNGKey(1), env_cfg, 9)

print(f"{'n_envs':>7} {'compile_s':>10} {'episode_s':>10} {'per_env_s':>10} "
      f"{'speedup':>8}")
t1 = None
for n in (1, 2, 4, 8):
    u0 = jnp.take(bank, jnp.arange(n) % 8, axis=0)
    fn = jax.jit(lambda p, u, k: rollout.rollout(p, pcfg, env_cfg, e_dns, u, k))
    t0 = time.perf_counter()
    fn.lower(params, u0, jax.random.PRNGKey(0)).compile()
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(fn(params, u0, jax.random.PRNGKey(2)))
    t_run = time.perf_counter() - t0
    t1 = t1 or t_run
    print(f"{n:7d} {t_compile:10.2f} {t_run:10.2f} {t_run/n:10.3f} "
          f"{n*t1/t_run:8.2f}")

print("\nOn the production mesh each env shard is independent (batch axis);")
print("the multi-pod dry-run proves the layout: "
      "python -m repro.launch.dryrun --all")

"""Scaling demo (paper Sec. 6.1): weak-scale the environment fleet and show
the launch-overhead amortization of the single-program design.

    PYTHONPATH=src python examples/scaling_demo.py
"""
import time

import jax

from repro import envs
from repro.core import policy, rollout

env = envs.make("hit_les_reduced")
pcfg = policy.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
params = policy.init(jax.random.PRNGKey(0), pcfg)
bank = env.initial_state_bank(jax.random.PRNGKey(1), 9)

print(f"{'n_envs':>7} {'compile_s':>10} {'episode_s':>10} {'per_env_s':>10} "
      f"{'speedup':>8}")
t1 = None
for n in (1, 2, 4, 8):
    u0 = jax.numpy.take(bank, jax.numpy.arange(n) % 8, axis=0)
    fn = jax.jit(lambda p, u, k: rollout.rollout(p, pcfg, env, u, k))
    t0 = time.perf_counter()
    fn.lower(params, u0, jax.random.PRNGKey(0)).compile()
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(fn(params, u0, jax.random.PRNGKey(2)))
    t_run = time.perf_counter() - t0
    t1 = t1 or t_run
    print(f"{n:7d} {t_compile:10.2f} {t_run:10.2f} {t_run/n:10.3f} "
          f"{n*t1/t_run:8.2f}")

print("\nOn the production mesh each env shard is independent (batch axis);")
print("the multi-pod dry-run proves the layout: "
      "python -m repro.launch.dryrun --all")

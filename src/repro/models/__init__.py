"""Assigned-architecture substrate: the ten LM-family configs share this
single composable stack.

  config       ArchConfig dataclass (static, hashable, jit-friendly)
  attention    GQA + RoPE + SWA + softcap; train/prefill/decode paths
  moe          DeepSeekMoE-style shared+routed experts, GShard dispatch
  ssm          Mamba-2-style selective SSM (hymba branch)
  rwkv         RWKV-6 time/channel mixing
  blocks       norm+mixer+FFN block assembly, per-layer kinds, caches
  lm           decoder-only assembly (scan over layer groups, chunked CE)
  encdec       whisper-style encoder-decoder
  api          uniform dispatch the launcher/dry-run program against
"""
from . import api, attention, blocks, config, encdec, lm, moe, rwkv, ssm
from .config import ArchConfig

__all__ = ["api", "attention", "blocks", "config", "encdec", "lm", "moe",
           "rwkv", "ssm", "ArchConfig"]

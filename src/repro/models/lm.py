"""Decoder-only LM assembly for the assigned architectures (all but whisper).

Layers are grouped for `lax.scan`: group size = the architecture's layer-kind
period (gemma-2 local/global = 2, hymba global-every-8 = 8, otherwise 1), so
every scan step executes an identical program.  MoE dense-prefix layers (the
deepseek/moonshot first layer) are unrolled before the scan.  Training remats
each group; the stored residual carry is sequence-sharded over `model`
(Megatron-style SP) so the 27B/35B cells fit HBM — see parallel/sharding.py.

The cross-entropy is computed in sequence chunks against the (vocab-sharded)
output head without ever materializing (B, S, V) logits.

Entry points (cfg is static):
    init(key, cfg, ...)                  parameter pytree (f32 masters)
    param_axes(cfg)                      logical-axis mirror for sharding
    lm_loss(params, cfg, batch)          scalar loss + metrics
    train_step(params, opt, batch, cfg)  one SGD step
    prefill(params, cfg, tokens, ...)    (last-token logits, caches)
    decode_step(params, cfg, token, c)   (logits, caches)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn, optim
from ..parallel import sharding
from . import blocks
from .config import ArchConfig


# --- structure helpers -----------------------------------------------------------
def group_size(cfg: ArchConfig) -> int:
    return cfg.window_pattern if cfg.window_pattern else 1


def n_prefix(cfg: ArchConfig) -> int:
    return cfg.first_dense_layers if cfg.ffn == "moe" else 0


def n_groups(cfg: ArchConfig) -> int:
    g = group_size(cfg)
    scanned = cfg.n_layers - n_prefix(cfg)
    assert scanned % g == 0, (cfg.name, scanned, g)
    return scanned // g


def group_kinds(cfg: ArchConfig) -> list[blocks.LayerKind]:
    """Layer kinds of the g blocks inside every scan group (kind depends on
    the layer index only through i % g, which grouping preserves)."""
    p = n_prefix(cfg)
    assert p == 0 or group_size(cfg) == 1, "dense prefix requires group=1"
    return [blocks.layer_kind(cfg, p + j) for j in range(group_size(cfg))]


def _stack(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# --- init -------------------------------------------------------------------------
def init(key: jax.Array, cfg: ArchConfig) -> dict:
    ke, kh, kp, kl, kproj = jax.random.split(key, 5)
    params: dict = {
        "embed": nn.embedding_init(ke, cfg.vocab, cfg.d_model),
        "final_norm": blocks.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": (1.0 / np.sqrt(cfg.d_model)) * jax.random.normal(
            kh, (cfg.d_model, cfg.vocab), jnp.float32)}

    pre = []
    for i in range(n_prefix(cfg)):
        kp, sub = jax.random.split(kp)
        pre.append(blocks.init_block(sub, cfg, blocks.layer_kind(cfg, i)))
    if pre:
        params["prefix"] = pre

    kinds = group_kinds(cfg)
    groups = []
    for m in range(n_groups(cfg)):
        kl, sub = jax.random.split(kl)
        subkeys = jax.random.split(sub, len(kinds))
        groups.append({f"b{j}": blocks.init_block(subkeys[j], cfg, kinds[j])
                       for j in range(len(kinds))})
    params["layers"] = _stack(groups)

    if cfg.vision_dim:  # llava projector (2-layer GELU MLP)
        k1, k2 = jax.random.split(kproj)
        s = 1.0 / np.sqrt(cfg.vision_dim)
        params["projector"] = {
            "w1": nn.dense_init(k1, cfg.vision_dim, cfg.d_model, bias=True),
            "w2": nn.dense_init(k2, cfg.d_model, cfg.d_model, bias=True),
        }
    return params


def param_axes(cfg: ArchConfig) -> dict:
    kinds = group_kinds(cfg)
    group_ax = {f"b{j}": blocks.block_axes(cfg, kinds[j])
                for j in range(len(kinds))}
    # scanned leaves gain a leading (n_groups) axis -> prepend None
    layers_ax = jax.tree.map(
        lambda ax: (None,) + tuple(ax),
        group_ax,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(s, str) or s is None for s in x),
    )
    ax: dict = {
        "embed": {"table": ("vocab", "embed")},
        "final_norm": blocks.norm_axes(cfg),
        "layers": layers_ax,
    }
    if not cfg.tie_embeddings:
        ax["head"] = {"w": ("embed", "vocab")}
    if n_prefix(cfg):
        ax["prefix"] = [blocks.block_axes(cfg, blocks.layer_kind(cfg, i))
                        for i in range(n_prefix(cfg))]
    if cfg.vision_dim:
        ax["projector"] = {"w1": {"w": (None, "embed"), "b": ("embed",)},
                           "w2": {"w": ("embed", "embed"), "b": ("embed",)}}
    return ax


# --- caches -------------------------------------------------------------------------
def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    kinds = group_kinds(cfg)
    group = {f"b{j}": blocks.init_block_cache(cfg, kinds[j], batch, max_len,
                                              dtype)
             for j in range(len(kinds))}
    stacked = jax.tree.map(
        lambda x: jnp.zeros((n_groups(cfg),) + x.shape, x.dtype), group)
    caches: dict = {"layers": stacked}
    if n_prefix(cfg):
        caches["prefix"] = [
            blocks.init_block_cache(cfg, blocks.layer_kind(cfg, i), batch,
                                    max_len, dtype)
            for i in range(n_prefix(cfg))]
    return caches


def cache_axes(cfg: ArchConfig) -> dict:
    kinds = group_kinds(cfg)
    group_ax = {f"b{j}": blocks.block_cache_axes(cfg)
                for j in range(len(kinds))}
    is_ax = lambda x: x is None or (isinstance(x, tuple) and all(
        isinstance(s, str) or s is None for s in x))
    layers_ax = jax.tree.map(
        lambda ax: (None,) + tuple(ax) if ax is not None else None,
        group_ax, is_leaf=is_ax)
    caxes: dict = {"layers": layers_ax}
    if n_prefix(cfg):
        caxes["prefix"] = [blocks.block_cache_axes(cfg)
                           for _ in range(n_prefix(cfg))]
    return caxes


# --- forward -------------------------------------------------------------------------
def embed_tokens(params: dict, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x.astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def project_patches(params: dict, cfg: ArchConfig, patches: jax.Array) -> jax.Array:
    h = nn.dense(params["projector"]["w1"], patches.astype(cfg.dtype))
    return nn.dense(params["projector"]["w2"], jax.nn.gelu(h))


def forward_hidden(params: dict, cfg: ArchConfig, x: jax.Array,
                   mode: str = "train", caches: dict | None = None
                   ) -> tuple[jax.Array, jax.Array, dict | None]:
    """Embedded input (B, S, D) -> (hidden, moe_aux (2,), new_caches)."""
    kinds = group_kinds(cfg)
    g = len(kinds)
    x = sharding.constrain(x, "batch", "act_seq", None)
    aux_total = jnp.zeros((2,), jnp.float32)
    new_prefix = []
    for i in range(n_prefix(cfg)):
        c = caches["prefix"][i] if caches else None
        x, aux, nc = blocks.apply_block(
            params["prefix"][i], cfg, blocks.layer_kind(cfg, i), x, mode, c)
        aux_total = aux_total + aux
        new_prefix.append(nc)

    def group_fn(x, scanned):
        p_g, c_g = scanned
        aux_g = jnp.zeros((2,), jnp.float32)
        new_c = {}
        for j in range(g):
            cj = c_g[f"b{j}"] if c_g is not None else None
            x, aux, ncj = blocks.apply_block(p_g[f"b{j}"], cfg, kinds[j], x,
                                             mode, cj)
            aux_g = aux_g + aux
            new_c[f"b{j}"] = ncj
        if any(v is None for v in new_c.values()):
            new_c = None
        return x, (aux_g, new_c)

    body = group_fn
    if cfg.remat and mode == "train":
        policy = {
            "dots": jax.checkpoint_policies.dots_saveable,
            "proj_dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            "save_gathered": jax.checkpoint_policies.save_only_these_names(
                "gathered_weights"),
        }.get(cfg.remat_policy, jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(group_fn, policy=policy)

    layer_caches = caches["layers"] if caches else None
    if cfg.scan_layers:
        x, (aux_seq, new_layer_caches) = jax.lax.scan(
            body, x, (params["layers"], layer_caches))
        aux_total = aux_total + jnp.sum(aux_seq, axis=0)
    else:
        new_list = []
        for m in range(n_groups(cfg)):
            p_m = jax.tree.map(lambda a, m=m: a[m], params["layers"])
            c_m = (jax.tree.map(lambda a, m=m: a[m], layer_caches)
                   if layer_caches is not None else None)
            x, (aux, nc) = body(x, (p_m, c_m))
            aux_total = aux_total + aux
            new_list.append(nc)
        new_layer_caches = None if new_list and new_list[0] is None else (
            _stack(new_list) if new_list else None)

    new_caches = None
    if mode != "train" and caches is not None:
        new_caches = {"layers": new_layer_caches}
        if new_prefix:
            new_caches["prefix"] = new_prefix
    return x, aux_total, new_caches


def _head_weight(params: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["table"].T  # (D, V)
    return params["head"]["w"]


def logits_for(params: dict, cfg: ArchConfig, hidden: jax.Array) -> jax.Array:
    """hidden (B, S, D) -> logits (B, S, V) (f32, softcapped)."""
    h = blocks.apply_norm(params["final_norm"], cfg, hidden)
    w = _head_weight(params, cfg).astype(h.dtype)
    logits = (h @ w).astype(jnp.float32)
    logits = sharding.constrain(logits, "batch", None, "vocab")
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


# --- loss ------------------------------------------------------------------------------
def chunked_ce(params: dict, cfg: ArchConfig, hidden: jax.Array,
               labels: jax.Array, mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing (B, S, V): scan over seq chunks.

    hidden: (B, S, D); labels, mask: (B, S).  Returns (nll_sum, count).
    """
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = jnp.moveaxis(hidden.reshape(b, n_chunks, chunk, d), 1, 0)
    yc = jnp.moveaxis(labels.reshape(b, n_chunks, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, n_chunks, chunk), 1, 0)

    def chunk_fn(carry, xs):
        x_c, y_c, m_c = xs
        logits = logits_for(params, cfg, x_c)  # (B, C, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(y_c, cfg.vocab, dtype=logits.dtype)
        ll = jnp.einsum("bcv,bcv->bc", logits, onehot)
        nll = (lse - ll) * m_c
        nll_sum, count = carry
        return (nll_sum + jnp.sum(nll), count + jnp.sum(m_c)), None

    body = jax.checkpoint(chunk_fn) if cfg.remat else chunk_fn
    carry = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if cfg.unroll_scans:  # dry-run calibration: no while loop in the HLO
        for i in range(n_chunks):
            carry, _ = body(carry, (hc[i], yc[i], mc[i]))
        nll_sum, count = carry
    else:
        (nll_sum, count), _ = jax.lax.scan(body, carry, (hc, yc, mc))
    return nll_sum, count


def lm_loss(params: dict, cfg: ArchConfig, batch: dict
            ) -> tuple[jax.Array, dict]:
    """batch: {"tokens" (B,S), "labels" (B,S), optional "patches"}."""
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    n_img = 0
    if cfg.vision_dim and "patches" in batch:
        img = project_patches(params, cfg, batch["patches"])
        n_img = img.shape[1]
        x = jnp.concatenate([img, x], axis=1)
    x, aux, _ = forward_hidden(params, cfg, x, mode="train")
    if n_img:  # positions [n_img-1, n_img+T-1) predict tok_0..tok_{T-1}
        x = x[:, n_img - 1: n_img - 1 + tokens.shape[1]]
    mask = batch.get("mask", jnp.ones_like(batch["labels"], jnp.float32))
    nll_sum, count = chunked_ce(params, cfg, x, batch["labels"],
                                mask.astype(jnp.float32))
    ce = nll_sum / jnp.maximum(count, 1.0)
    lb, z = aux[0], aux[1]
    loss = ce + 0.01 * lb + 1e-3 * z
    return loss, {"loss": loss, "ce": ce, "moe_lb": lb, "router_z": z,
                  "tokens": count}


def train_step(params: dict, opt_state: optim.adam.AdamState, batch: dict,
               cfg: ArchConfig, adam_cfg: optim.AdamConfig | None = None):
    """One synchronous data-parallel training step."""
    adam_cfg = adam_cfg or optim.AdamConfig(lr=3e-4, grad_clip=1.0)
    (loss, metrics), grads = jax.value_and_grad(lm_loss, has_aux=True)(
        params, cfg, batch)
    metrics["grad_norm"] = optim.global_norm(grads)
    params, opt_state = optim.adam_update(adam_cfg, params, grads, opt_state)
    return params, opt_state, metrics


# --- serving -------------------------------------------------------------------------
def prefill(params: dict, cfg: ArchConfig, tokens: jax.Array,
            patches: jax.Array | None = None, cache_len: int | None = None,
            cache_dtype=jnp.bfloat16) -> tuple[jax.Array, dict]:
    """Process the prompt, build caches.  Returns (last-token logits, caches)."""
    b, s = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    if cfg.vision_dim and patches is not None:
        img = project_patches(params, cfg, patches)
        x = jnp.concatenate([img, x], axis=1)
    total = x.shape[1]
    caches = init_caches(cfg, b, cache_len or total, cache_dtype)
    x, _, caches = forward_hidden(params, cfg, x, mode="prefill", caches=caches)
    logits = logits_for(params, cfg, x[:, -1:])[:, 0]
    return logits, caches


def decode_step(params: dict, cfg: ArchConfig, token: jax.Array, caches: dict
                ) -> tuple[jax.Array, dict]:
    """One decode step.  token: (B,) int32 -> (logits (B, V), caches)."""
    x = embed_tokens(params, cfg, token[:, None])
    x, _, caches = forward_hidden(params, cfg, x, mode="decode", caches=caches)
    logits = logits_for(params, cfg, x)[:, 0]
    return logits, caches


def greedy_generate(params: dict, cfg: ArchConfig, prompt: jax.Array,
                    n_new: int) -> jax.Array:
    """Greedy decoding loop (examples / tests).  prompt: (B, S)."""
    logits, caches = prefill(params, cfg, prompt,
                             cache_len=prompt.shape[1] + n_new)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def step(carry, _):
        tok, caches = carry
        logits, caches = decode_step(params, cfg, tok, caches)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, caches), nxt

    (_, _), toks = jax.lax.scan(step, (tok0, caches), None, length=n_new - 1)
    return jnp.concatenate([tok0[None], toks], axis=0).T  # (B, n_new)

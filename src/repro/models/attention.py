"""GQA attention for the assigned LM architectures.

Covers: grouped-query attention, RoPE, sliding windows (ring-buffer KV
caches), gemma-2 logit softcapping, optional QKV biases.  Three entry
points matching the three cell kinds:

  full_attention     train_4k        — causal self-attention, no cache
  prefill_attention  prefill_32k     — causal self-attention + cache build
  decode_attention   decode/long     — one token against a KV cache

Decode against a sequence-sharded cache supports two combine strategies:

  "allgather"  (baseline) let XLA SPMD all-gather the KV shard — what a
               naive pjit of the math produces; moves O(S*D*Hkv) per step.
  "flash"      flash-decoding: shard_map over the cache's mesh axis, each
               shard attends to its KV slice and emits (out, logsumexp);
               a tiny psum-combine merges the partial softmaxes — moves
               O(Hq*D) per step.  The §Perf hillclimb quantifies the gap.

Compute dtype follows the inputs; softmax statistics are always f32.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import nn
from ..kernels import ops as kops
from ..parallel import sharding
from .config import ArchConfig


# --- RoPE --------------------------------------------------------------------
def rope_table(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """(cos, sin) tables for `positions` (any shape) -> (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x[..., :h], x[..., h:]) — the neox/llama convention.

    x: (B, H, S, D); cos/sin: (S, D/2) or broadcastable (B, 1, S, D/2).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    while cos.ndim < x1.ndim:  # (S, h) -> (1, 1, S, h)
        cos, sin = cos[None], sin[None]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x1f * sin + x2f * cos], axis=-1)
    return out.astype(x.dtype)


# --- parameters ---------------------------------------------------------------
def init(key: jax.Array, cfg: ArchConfig) -> dict:
    """One attention block's parameters."""
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": nn.dense_init(kq, d, cfg.n_heads * hd, bias=cfg.attn_bias),
        "wk": nn.dense_init(kk, d, cfg.kv_heads * hd, bias=cfg.attn_bias),
        "wv": nn.dense_init(kv, d, cfg.kv_heads * hd, bias=cfg.attn_bias),
        "wo": nn.dense_init(ko, cfg.n_heads * hd, d, bias=cfg.attn_bias),
    }
    return p


def axes(cfg: ArchConfig) -> dict:
    """Logical axes mirroring `init` (see parallel.sharding.param_specs)."""
    def with_bias(ax):
        return {"w": ax, "b": (ax[-1],)} if cfg.attn_bias else {"w": ax}

    return {
        "wq": with_bias(("embed", "heads")),
        "wk": with_bias(("embed", "kv_heads")),
        "wv": with_bias(("embed", "kv_heads")),
        "wo": with_bias(("heads", "embed")),
    }


# --- cache --------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int, *, window: int | None,
               dtype=jnp.bfloat16) -> dict:
    """Empty KV cache for one layer.  Sliding-window layers get a ring
    buffer bounded by the window; global layers a full-length buffer."""
    length = min(window, max_len) if window else max_len
    shape = (batch, cfg.kv_heads, length, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),  # absolute position of the next write
    }


def cache_axes() -> dict:
    return {
        "k": ("batch", "kv_heads", "kv_seq", None),
        "v": ("batch", "kv_heads", "kv_seq", None),
        "pos": None,
    }


def _qkv(p: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x (B, S, D) -> q (B, Hq, S, hd), k/v (B, Hkv, S, hd)."""
    b, s, _ = x.shape
    hd = cfg.hd
    q = nn.dense(p["wq"], x, dtype=x.dtype).reshape(b, s, cfg.n_heads, hd)
    k = nn.dense(p["wk"], x, dtype=x.dtype).reshape(b, s, cfg.kv_heads, hd)
    v = nn.dense(p["wv"], x, dtype=x.dtype).reshape(b, s, cfg.kv_heads, hd)
    q, k, v = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
    q = sharding.constrain(q, "batch", "heads", None, None)
    k = sharding.constrain(k, "batch", "kv_heads", None, None)
    v = sharding.constrain(v, "batch", "kv_heads", None, None)
    return q, k, v


def _out(p: dict, cfg: ArchConfig, o: jax.Array) -> jax.Array:
    """o (B, Hq, S, hd) -> (B, S, D)."""
    b, _, s, _ = o.shape
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, cfg.n_heads * cfg.hd)
    return nn.dense(p["wo"], o, dtype=o.dtype)


# --- train / prefill -----------------------------------------------------------
def full_attention(
    p: dict, cfg: ArchConfig, x: jax.Array, *, window: int | None,
    positions: jax.Array | None = None,
) -> jax.Array:
    """Causal self-attention over the whole sequence (training path)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    if cfg.rope:
        pos = jnp.arange(s) if positions is None else positions
        cos, sin = rope_table(pos, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    o = kops.attention(
        q, k, v, causal=True, window=window,
        softcap=cfg.attn_softcap or None, scale=cfg.attn_scale or None,
        impl=cfg.attn_impl, block_k=cfg.attn_block_k,
        unroll=cfg.unroll_scans,
    )
    return _out(p, cfg, o)


def prefill_attention(
    p: dict, cfg: ArchConfig, x: jax.Array, cache: dict, *, window: int | None,
) -> tuple[jax.Array, dict]:
    """Causal self-attention + cache population (prefill path).

    Assumes an empty cache (pos == 0) and s <= cache length for global
    layers; sliding-window layers keep only the trailing `window` keys.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    if cfg.rope:
        cos, sin = rope_table(jnp.arange(s), cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    o = kops.attention(
        q, k, v, causal=True, window=window,
        softcap=cfg.attn_softcap or None, scale=cfg.attn_scale or None,
        impl=cfg.attn_impl, block_k=cfg.attn_block_k,
        unroll=cfg.unroll_scans,
    )
    length = cache["k"].shape[2]
    if length >= s:  # global layer: write [0, s)
        k_new = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        v_new = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    else:  # ring buffer: keep the last `length` positions, slot = pos % length
        tail_k = k[:, :, s - length:, :].astype(cache["k"].dtype)
        tail_v = v[:, :, s - length:, :].astype(cache["v"].dtype)
        slots = (jnp.arange(length) + (s - length)) % length
        k_new = jnp.zeros_like(cache["k"]).at[:, :, slots, :].set(tail_k)
        v_new = jnp.zeros_like(cache["v"]).at[:, :, slots, :].set(tail_v)
    new_cache = {"k": k_new, "v": v_new, "pos": jnp.asarray(s, jnp.int32)}
    return _out(p, cfg, o), new_cache


# --- decode ---------------------------------------------------------------------
def _partial_softmax_attn(q, k, v, mask, softcap, scale):
    """Attention over a KV slice returning partial-softmax statistics.

    q: (B, Hq, 1, D); k/v: (B, Hkv, L, D); mask: (B, 1, 1, L) or (1,1,1,L).
    Returns (acc, m, l): acc (B, Hq, 1, D) = sum exp(logits - m_safe) * v,
    m (B, Hq, 1) the row max (-inf when fully masked), l (B, Hq, 1) the
    exp-sum.  out = acc / l locally; cross-shard combining rescales by
    exp(m - m_max) first (flash-decoding).
    """
    group = q.shape[1] // k.shape[1]
    kg = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vg = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kg) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)  # (B, Hq, 1)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqk,bhkd->bhqd", p, vg)
    return acc, m, l


def decode_attention(
    p: dict, cfg: ArchConfig, x: jax.Array, cache: dict, *, window: int | None,
    combine: str = "allgather",
) -> tuple[jax.Array, dict]:
    """One-token attention against the cache.  x: (B, 1, D)."""
    b = x.shape[0]
    q, k, v = _qkv(p, cfg, x)  # (B, H*, 1, hd)
    pos = cache["pos"]  # absolute position of this token
    if cfg.rope:
        cos, sin = rope_table(pos[None], cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    length = cache["k"].shape[2]
    slot = (pos % length) if window else jnp.minimum(pos, length - 1)
    scale = cfg.attn_scale or cfg.hd ** -0.5

    if combine == "flash":
        rules = sharding.current_rules()
        axis = rules.mesh_axes("kv_seq") if rules else None
        if rules is not None and rules.mesh is not None and axis is not None \
                and length % rules.mesh.shape[axis] == 0:
            o, k_cache, v_cache = _flash_decode(
                q, cache["k"], cache["v"], k, v, pos, slot,
                bool(window), cfg.attn_softcap or 0.0, scale, rules, axis)
            new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
            return _out(p, cfg, o.astype(x.dtype)), new_cache
        # fall through to the dense path when no mesh/axis applies

    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}

    # Valid-slot mask.  Ring buffer: slot s holds absolute position
    # pos - ((pos - s) mod L) <= pos, all within (pos-L, pos] -> valid iff
    # written (abs position <= pos, automatically true once warm; cold slots
    # s > pos are excluded).  Global buffer: slots [0, pos] valid.
    slots = jnp.arange(length)
    if window:
        abs_pos = pos - jnp.mod(pos - slots, length)
        mask = abs_pos >= 0
    else:
        mask = slots <= pos
    mask = mask[None, None, None, :]

    k_cache = sharding.constrain(k_cache, "batch", "kv_heads", "kv_seq", None)
    v_cache = sharding.constrain(v_cache, "batch", "kv_heads", "kv_seq", None)
    acc, _, l = _partial_softmax_attn(q, k_cache, v_cache, mask,
                                      cfg.attn_softcap or 0.0, scale)
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return _out(p, cfg, o.astype(x.dtype)), new_cache


def _flash_decode(q, k_cache, v_cache, k_new, v_new, pos, slot, is_window,
                  softcap, scale, rules, axis):
    """Flash-decoding with a SHARD-LOCAL cache update.

    Two things must stay local to the sequence shard or XLA SPMD gathers the
    whole cache every step (measured: ~86 GB/step on command-r decode_32k):
      1. the single-token dynamic_update_slice (a dynamic index into a
         sharded dim) — done here with shard-local slot arithmetic;
      2. the softmax over the sharded KV axis — partial (acc, max, sum)
         statistics merge with an O(B*Hq*D) psum:
         out = sum_i acc_i·exp(m_i-m_max) / sum_i l_i·exp(m_i-m_max).
    """
    mesh = rules.mesh
    length = k_cache.shape[2]
    n_shards = mesh.shape[axis]
    local_len = length // n_shards
    batch_axes = rules.mesh_axes("batch")
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    b_ax = tuple(a for a in (batch_axes or ()) if a in mesh.shape
                 and q.shape[0] % mesh.shape[a] == 0) or None
    b_spec = b_ax if b_ax is None else (b_ax if len(b_ax) > 1 else b_ax[0])

    def shard_fn(q_s, kc, vc, kn, vn, pos_s, slot_s):
        idx = jax.lax.axis_index(axis)
        local_slot = slot_s - idx * local_len
        in_range = (local_slot >= 0) & (local_slot < local_len)
        safe = jnp.clip(local_slot, 0, local_len - 1)
        kc_upd = jax.lax.dynamic_update_slice(
            kc, kn.astype(kc.dtype), (0, 0, safe, 0))
        vc_upd = jax.lax.dynamic_update_slice(
            vc, vn.astype(vc.dtype), (0, 0, safe, 0))
        kc = jnp.where(in_range, kc_upd, kc)
        vc = jnp.where(in_range, vc_upd, vc)
        abs_slots = idx * local_len + jnp.arange(local_len)
        if is_window:
            mask = (pos_s - jnp.mod(pos_s - abs_slots, length)) >= 0
        else:
            mask = abs_slots <= pos_s
        acc, m, l = _partial_softmax_attn(q_s, kc, vc,
                                          mask[None, None, None, :],
                                          softcap, scale)
        m_max = jax.lax.pmax(m, axis)  # decode always has >= 1 valid key
        w = jnp.exp(m - m_max)         # 0 on fully-masked shards (m = -inf)
        num = jax.lax.psum(acc * w[..., None], axis)
        den = jax.lax.psum(l * w, axis)
        return num / jnp.maximum(den, 1e-30)[..., None], kc, vc

    from jax.experimental.shard_map import shard_map
    spec_kv = P(b_spec, None, axis, None)
    spec_tok = P(b_spec, None, None, None)
    return shard_map(
        shard_fn, mesh=mesh,
        in_specs=(spec_tok, spec_kv, spec_kv, spec_tok, spec_tok, P(), P()),
        out_specs=(spec_tok, spec_kv, spec_kv),
        check_rep=False,
    )(q, k_cache, v_cache, k_new, v_new, pos, slot)

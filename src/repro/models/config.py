"""ArchConfig: one dataclass covering all ten assigned architectures.

Every field is static/hashable so ArchConfig can be a jit static argument.
`src/repro/configs/<id>.py` instantiates the exact published configs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int               # query heads (attention mixers)
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads

    # block structure
    mixer: str = "attn"        # attn | mamba | rwkv | attn+mamba
    ffn: str = "swiglu"        # swiglu | geglu | gelu_mlp | moe | rwkv_cmix
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    norm_scale_plus_one: bool = False  # gemma (1 + scale) RMSNorm
    post_norms: bool = False   # gemma-2 sandwich norms
    parallel_block: bool = False  # command-r: attn & ffn from the same norm
    attn_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: embeddings * sqrt(d_model)
    logit_softcap: float = 0.0  # 0 -> off
    attn_softcap: float = 0.0

    # attention geometry
    rope: bool = True
    rope_theta: float = 10000.0
    attn_scale: float = 0.0    # 0 -> head_dim**-0.5 (gemma-2: query_pre_attn)
    max_positions: int = 32768  # learned-pos archs (whisper) table size
    window: int = 0            # sliding-window size; 0 -> full attention
    window_pattern: int = 0    # gemma-2: layer i is GLOBAL iff i % pattern
    #                            == pattern-1; 0 -> window on all layers

    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_dense: int = 0        # dense FFN width of the first layers
    first_dense_layers: int = 0
    moe_capacity_factor: float = 1.25
    norm_topk: bool = True     # renormalize top-k gates (moonshot yes, deepseek no)
    moe_group_size: int = 4096  # GShard dispatch group (tokens)

    # ssm / rwkv
    ssm_state: int = 0
    d_conv: int = 4
    rwkv_lora: int = 32        # token-shift mix lora rank
    rwkv_decay_lora: int = 64  # data-dependent decay lora rank

    # enc-dec / modality frontends (STUBS per the brief)
    encoder_layers: int = 0    # >0 -> whisper-style enc-dec
    max_source_positions: int = 1500
    vision_dim: int = 0        # llava: precomputed patch-embedding width
    vision_tokens: int = 576   # anyres base grid (24x24) — stub frontend

    # numerics / implementation
    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # f32 training masters; serve in bf16
    attn_impl: str = "chunked"   # kernel | chunked | naive
    scan_impl: str = "chunked"   # kernel | chunked | scan
    attn_block_k: int = 1024
    scan_chunk: int = 64
    remat: bool = True           # checkpoint each layer group in training
    remat_policy: str = "nothing"  # nothing | dots | proj_dots
    #                              (proj_dots = dots_with_no_batch_dims:
    #                               save x@W outputs, recompute attention)
    scan_layers: bool = True     # lax.scan over layer stacks
    decode_combine: str = "allgather"  # seq-sharded KV combine: allgather|flash
    loss_chunk: int = 512        # chunked cross-entropy sequence chunk
    unroll_scans: bool = False   # python-unroll inner seq scans (dry-run
    #                              calibration: XLA cost_analysis counts
    #                              while bodies ONCE; see launch/dryrun.py)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def group_size(self) -> int:
        """Layers per scan group (window_pattern or dense-prefix handling)."""
        return self.window_pattern if self.window_pattern else 1

    def layer_is_global(self, i: int) -> bool:
        """Full-attention layer? (gemma-2 local/global alternation)."""
        if self.window == 0:
            return True
        if self.window_pattern == 0:
            return False
        return i % self.window_pattern == self.window_pattern - 1

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.mixer in ("rwkv",)

    # rough parameter count (reported in DESIGN.md; exact count from tests)
    def approx_params(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab
        per_layer = 0
        if "attn" in self.mixer:
            per_layer += d * self.hd * (self.n_heads + 2 * self.kv_heads) \
                + self.n_heads * self.hd * d
        if "mamba" in self.mixer:
            per_layer += 2 * d * d + d * (2 * self.ssm_state * self.n_heads)
        if self.mixer == "rwkv":
            per_layer += 4 * d * d + 2 * d * 64
        if self.ffn == "moe":
            expert = 3 * d * ff
            per_layer += self.n_experts * expert \
                + self.n_shared_experts * expert + d * self.n_experts
        elif self.ffn == "swiglu" or self.ffn == "geglu":
            per_layer += 3 * d * ff
        else:
            per_layer += 2 * d * ff
        total = self.n_layers * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.is_encdec:
            total += self.encoder_layers * (4 * d * d + 2 * d * ff)
        return total

"""Mamba-2-style selective SSM head mixer (hymba-1.5b's SSM branch).

Multi-head gated linear recurrence (the Mamba-2 "state space duality" form):
per head h with head dim P and state size N,

    S_t = exp(-softplus(a_h) * dt_t) * S_{t-1} + dt_t * B_t x_t^T     (N, P)
    y_t = C_t @ S_t + D_h * x_t

i.e. a gated-linear-attention read with q=C, k=B*dt, data-dependent scalar-
per-head decay w_t = exp(-softplus(a) dt_t) broadcast over the N axis, plus
a skip D and an output gate z (SiLU).  The sequential dependence runs
through kernels.ops.gated_linear_scan (decay_before_read=True), the
chunk-parallel Pallas kernel / jnp reference pair — which is what makes the
long_500k cells O(T) with O(1) state.

The depthwise causal conv (width d_conv) matches Mamba's local mixing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn
from ..kernels import ops as kops
from ..parallel import sharding
from .config import ArchConfig


def _dims(cfg: ArchConfig) -> tuple[int, int, int]:
    """(n_heads, head_dim, d_inner) of the SSM branch."""
    return cfg.n_heads, cfg.hd, cfg.n_heads * cfg.hd


def init(key: jax.Array, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    h, p, d_in = _dims(cfg)
    n = cfg.ssm_state
    kx, kb, kc, kdt, kz, ko, kconv = jax.random.split(key, 7)
    scale = 1.0 / np.sqrt(d)
    return {
        "wx": {"w": scale * jax.random.normal(kx, (d, d_in), jnp.float32)},
        "wz": {"w": scale * jax.random.normal(kz, (d, d_in), jnp.float32)},
        "wb": {"w": scale * jax.random.normal(kb, (d, h * n), jnp.float32)},
        "wc": {"w": scale * jax.random.normal(kc, (d, h * n), jnp.float32)},
        "wdt": {"w": scale * jax.random.normal(kdt, (d, h), jnp.float32),
                "b": jnp.asarray(
                    np.log(np.expm1(np.geomspace(1e-3, 0.1, h))), jnp.float32)},
        "a_log": jnp.zeros((h,), jnp.float32),   # softplus(a)=log1p(e^0)~0.69
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv": {"w": (1.0 / np.sqrt(cfg.d_conv)) *
                 jax.random.normal(kconv, (cfg.d_conv, d_in), jnp.float32)},
        "wo": {"w": (1.0 / np.sqrt(d_in)) *
               jax.random.normal(ko, (d_in, d), jnp.float32)},
    }


def axes(cfg: ArchConfig) -> dict:
    return {
        "wx": {"w": ("embed", "heads")},
        "wz": {"w": ("embed", "heads")},
        "wb": {"w": ("embed", "heads")},
        "wc": {"w": ("embed", "heads")},
        "wdt": {"w": ("embed", "heads"), "b": ("heads",)},
        "a_log": ("heads",),
        "d_skip": ("heads",),
        "conv": {"w": (None, "heads")},
        "wo": {"w": ("heads", "embed")},
    }


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    """Decode-time carry: SSM state + conv tail."""
    h, p, d_in = _dims(cfg)
    return {
        "s": jnp.zeros((batch, h, cfg.ssm_state, p), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in), dtype),
    }


def state_axes() -> dict:
    return {"s": ("batch", "heads", None, None),
            "conv": ("batch", None, "heads")}


def _causal_conv(p: dict, x: jax.Array, tail: jax.Array | None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along seq.  x: (B, T, D_in).  Returns
    (conv(x), new_tail (B, d_conv-1, D_in))."""
    w = p["w"].astype(x.dtype)  # (K, D_in)
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_tail = xp[:, xp.shape[1] - (k - 1):, :]
    return out, new_tail


def _branch_inputs(params: dict, cfg: ArchConfig, x: jax.Array,
                   conv_tail: jax.Array | None):
    """Shared pre-scan computation.  x: (B, T, D)."""
    b, t, _ = x.shape
    h, pdim, d_in = _dims(cfg)
    n = cfg.ssm_state
    xin = nn.dense(params["wx"], x, dtype=x.dtype)
    xin, new_tail = _causal_conv(params["conv"], xin, conv_tail)
    xin = jax.nn.silu(xin)
    z = jax.nn.silu(nn.dense(params["wz"], x, dtype=x.dtype))
    bmat = nn.dense(params["wb"], x, dtype=x.dtype).reshape(b, t, h, n)
    cmat = nn.dense(params["wc"], x, dtype=x.dtype).reshape(b, t, h, n)
    dt = jax.nn.softplus(
        nn.dense(params["wdt"], x, dtype=jnp.float32).astype(jnp.float32))
    a = jax.nn.softplus(params["a_log"])[None, None, :]          # (1,1,H)
    w = jnp.exp(-a * dt)                                          # (B,T,H)
    xv = xin.reshape(b, t, h, pdim)
    return xv, z, bmat, cmat, dt, w, new_tail


def apply_seq(params: dict, cfg: ArchConfig, x: jax.Array,
              state: dict | None = None) -> tuple[jax.Array, dict]:
    """Full-sequence SSM mixing.  x: (B, T, D) -> (out, new_state)."""
    b, t, _ = x.shape
    h, pdim, d_in = _dims(cfg)
    n = cfg.ssm_state
    conv_tail = state["conv"] if state is not None else None
    s0 = state["s"] if state is not None else None
    xv, z, bmat, cmat, dt, w, new_tail = _branch_inputs(params, cfg, x, conv_tail)

    # per-head gated linear scan: q=C, k=dt*B, v=x, decay w broadcast over N
    q = cmat.transpose(0, 2, 1, 3).reshape(b * h, t, n)
    k = (bmat * dt[..., None]).transpose(0, 2, 1, 3).reshape(b * h, t, n)
    v = xv.transpose(0, 2, 1, 3).reshape(b * h, t, pdim)
    wfull = jnp.broadcast_to(w.transpose(0, 2, 1)[..., None],
                             (b, h, t, n)).reshape(b * h, t, n)
    s0_flat = s0.reshape(b * h, n, pdim) if s0 is not None else None
    o, s_fin = kops.gated_linear_scan(
        q, k, v, wfull, None, s0_flat, decay_before_read=True,
        impl=cfg.scan_impl, chunk=cfg.scan_chunk, unroll=cfg.unroll_scans)
    o = o.reshape(b, h, t, pdim).transpose(0, 2, 1, 3)
    o = o + params["d_skip"][None, None, :, None] * xv
    o = (o.reshape(b, t, d_in) * z).astype(x.dtype)
    out = nn.dense(params["wo"], o, dtype=x.dtype)
    tail_dtype = state["conv"].dtype if state is not None else x.dtype
    new_state = {"s": s_fin.reshape(b, h, n, pdim),
                 "conv": new_tail.astype(tail_dtype)}
    return out, new_state


def apply_step(params: dict, cfg: ArchConfig, x: jax.Array, state: dict
               ) -> tuple[jax.Array, dict]:
    """Single-token decode step.  x: (B, 1, D)."""
    return apply_seq(params, cfg, x, state)

"""RWKV-6 "Finch" blocks (rwkv6-1.6b): attention-free linear RNN with
data-dependent decay (Peng et al. 2024, arXiv:2404.05892).

Time-mix:   token-shift interpolation with data-dependent mix (lora),
            r/k/v/gate projections, per-channel data-dependent decay
            w_t = exp(-exp(decay_t)), bonus u for the current token, and
            the WKV recurrence — kernels.ops.gated_linear_scan with
            decay_before_read=False (RWKV reads S_{t-1} + u*kv_t).
Channel-mix: token-shifted squared-ReLU MLP with receptance gate.

Heads have a fixed head dim (64 at 1.6B scale); the per-head (hd, hd) WKV
state is the entire sequence memory — what makes the long_500k cell O(1)
in context length.

Decode carries: {wkv state (B,H,hd,hd), time-mix shift (B,D), channel-mix
shift (B,D)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn
from ..kernels import ops as kops
from .config import ArchConfig

_MIX_KEYS = ("r", "k", "v", "w", "g")


def _dims(cfg: ArchConfig) -> tuple[int, int]:
    hd = cfg.hd
    return cfg.d_model // hd, hd


def init_time_mix(key: jax.Array, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    h, hd = _dims(cfg)
    lora, dlora = cfg.rwkv_lora, cfg.rwkv_decay_lora
    keys = jax.random.split(key, 12)
    s = 1.0 / np.sqrt(d)
    p = {
        # token-shift base mixes + the low-rank data-dependent part
        "mix_base": 0.5 * jnp.ones((len(_MIX_KEYS), d), jnp.float32),
        "mix_lora_a": {"w": s * jax.random.normal(keys[0], (d, len(_MIX_KEYS) * lora), jnp.float32)},
        "mix_lora_b": s * jax.random.normal(keys[1], (len(_MIX_KEYS), lora, d), jnp.float32),
        "wr": {"w": s * jax.random.normal(keys[2], (d, d), jnp.float32)},
        "wk": {"w": s * jax.random.normal(keys[3], (d, d), jnp.float32)},
        "wv": {"w": s * jax.random.normal(keys[4], (d, d), jnp.float32)},
        "wg": {"w": s * jax.random.normal(keys[5], (d, d), jnp.float32)},
        "decay_base": -6.0 * jnp.ones((d,), jnp.float32),  # w ~ exp(-exp(-6))
        "decay_lora_a": {"w": s * jax.random.normal(keys[6], (d, dlora), jnp.float32)},
        "decay_lora_b": {"w": s * jax.random.normal(keys[7], (dlora, d), jnp.float32)},
        "u_bonus": jnp.zeros((d,), jnp.float32),
        "out_norm": nn.layernorm_init(hd),  # per-head group norm
        "wo": {"w": s * jax.random.normal(keys[8], (d, d), jnp.float32)},
    }
    return p


def time_mix_axes(cfg: ArchConfig) -> dict:
    return {
        "mix_base": (None, "embed"),
        "mix_lora_a": {"w": ("embed", None)},
        "mix_lora_b": (None, None, "embed"),
        "wr": {"w": ("embed", "heads")},
        "wk": {"w": ("embed", "heads")},
        "wv": {"w": ("embed", "heads")},
        "wg": {"w": ("embed", "heads")},
        "decay_base": ("embed",),
        "decay_lora_a": {"w": ("embed", None)},
        "decay_lora_b": {"w": (None, "embed")},
        "u_bonus": ("embed",),
        "out_norm": {"scale": (None,), "bias": (None,)},
        "wo": {"w": ("heads", "embed")},
    }


def init_channel_mix(key: jax.Array, cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(d)
    return {
        "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_r": 0.5 * jnp.ones((d,), jnp.float32),
        "wk": {"w": s * jax.random.normal(k1, (d, f), jnp.float32)},
        "wv": {"w": (1.0 / np.sqrt(f)) * jax.random.normal(k2, (f, d), jnp.float32)},
        "wr": {"w": s * jax.random.normal(k3, (d, d), jnp.float32)},
    }


def channel_mix_axes(cfg: ArchConfig) -> dict:
    return {
        "mix_k": ("embed",),
        "mix_r": ("embed",),
        "wk": {"w": ("embed", "mlp")},
        "wv": {"w": ("mlp", "embed")},
        "wr": {"w": ("embed", "heads")},
    }


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    h, hd = _dims(cfg)
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_t": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_c": jnp.zeros((batch, cfg.d_model), dtype),
    }


def state_axes() -> dict:
    return {"wkv": ("batch", "heads", None, None),
            "shift_t": ("batch", "embed"),
            "shift_c": ("batch", "embed")}


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x_{t-1} along the sequence; position 0 sees `prev` (or zeros)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def time_mix(p: dict, cfg: ArchConfig, x: jax.Array,
             wkv_state: jax.Array | None, shift: jax.Array | None
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """RWKV6 time mixing.  x: (B, T, D) -> (out, wkv_state', shift')."""
    b, t, d = x.shape
    h, hd = _dims(cfg)
    lora = cfg.rwkv_lora
    xs = _token_shift(x, shift)
    delta = (xs - x).astype(jnp.float32)

    # data-dependent token-shift mixes (one per r/k/v/w/g)
    la = nn.dense(p["mix_lora_a"], x, dtype=jnp.float32)          # (B,T,5*lora)
    la = jnp.tanh(la).reshape(b, t, len(_MIX_KEYS), lora)
    dyn = jnp.einsum("btml,mld->btmd", la, p["mix_lora_b"])       # (B,T,5,D)
    mixes = p["mix_base"][None, None] + dyn                       # (B,T,5,D)
    xi = x.astype(jnp.float32)[:, :, None, :] + mixes * delta[:, :, None, :]
    xr, xk, xv, xw, xg = (xi[:, :, i, :].astype(x.dtype)
                          for i in range(len(_MIX_KEYS)))

    r = nn.dense(p["wr"], xr, dtype=x.dtype).reshape(b, t, h, hd)
    k = nn.dense(p["wk"], xk, dtype=x.dtype).reshape(b, t, h, hd)
    v = nn.dense(p["wv"], xv, dtype=x.dtype).reshape(b, t, h, hd)
    g = jax.nn.silu(nn.dense(p["wg"], xg, dtype=x.dtype))
    decay = p["decay_base"][None, None] + nn.dense(
        p["decay_lora_b"],
        jnp.tanh(nn.dense(p["decay_lora_a"], xw, dtype=jnp.float32)),
        dtype=jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, h, hd)             # in (0, 1)

    q_ = r.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    k_ = k.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    v_ = v.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    w_ = w.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    u = p["u_bonus"].reshape(h, hd)  # current-token bonus per channel
    s0 = wkv_state.reshape(b * h, hd, hd) if wkv_state is not None else None

    # per-head u: fold u into the scan by head -> loop over heads is wasteful;
    # instead scan with u broadcast via batch trick: reshape so the head axis
    # rides the batch axis and u differs per batch row.  ops.gated_linear_scan
    # takes a single (dk,) u, so we pass u via the k/v bonus identity:
    #   o_t = r (S_{t-1} + diag(u_h) k v^T)  ==  scan(u=0) + (r . (u_h*k)) v
    o, s_fin = kops.gated_linear_scan(
        q_, k_, v_, w_, None, s0, decay_before_read=False,
        impl=cfg.scan_impl, chunk=cfg.scan_chunk, unroll=cfg.unroll_scans)
    u_bh = jnp.repeat(u[None], b, axis=0).reshape(b * h, 1, hd)
    bonus = jnp.sum(q_ * (u_bh * k_), axis=-1, keepdims=True) * v_
    o = o + bonus

    o = o.reshape(b, h, t, hd).transpose(0, 2, 1, 3)              # (B,T,H,hd)
    o = nn.layernorm(p["out_norm"], o)                            # group norm
    o = (o.reshape(b, t, d) * g).astype(x.dtype)
    out = nn.dense(p["wo"], o, dtype=x.dtype)
    shift_dtype = shift.dtype if shift is not None else x.dtype
    return out, s_fin.reshape(b, h, hd, hd), x[:, -1].astype(shift_dtype)


def channel_mix(p: dict, cfg: ArchConfig, x: jax.Array,
                shift: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """RWKV channel mixing (squared-ReLU MLP with receptance gate)."""
    xs = _token_shift(x, shift)
    xk = x + p["mix_k"].astype(x.dtype) * (xs - x)
    xr = x + p["mix_r"].astype(x.dtype) * (xs - x)
    kk = jnp.square(jax.nn.relu(nn.dense(p["wk"], xk, dtype=x.dtype)))
    vv = nn.dense(p["wv"], kk, dtype=x.dtype)
    r = jax.nn.sigmoid(nn.dense(p["wr"], xr, dtype=x.dtype))
    shift_dtype = shift.dtype if shift is not None else x.dtype
    return r * vv, x[:, -1].astype(shift_dtype)

"""Mixture-of-experts FFN (deepseek-moe-16b / moonshot-v1-16b-a3b).

Fine-grained MoE: `n_experts` routed experts with top-k gating plus
`n_shared_experts` always-on shared experts (DeepSeekMoE, Dai et al. 2024).
TPU-idiomatic GShard-style dispatch: tokens are blocked into groups, each
group dispatches into per-expert capacity buffers through one-hot einsums,
and expert weights shard over the `experts` logical axis (EP) — XLA inserts
the token all-to-all from the sharding constraints.  This is the dense-
capacity equivalent of "dropless" GPU token routing (see DESIGN.md §2):
tokens beyond an expert's capacity drop to the shared/residual path, which
the capacity factor makes rare.

Aux losses: load-balancing (Switch) and router z-loss, returned for the
training objective.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn
from ..parallel import sharding
from .config import ArchConfig


def _capacity(group_size: int, cfg: ArchConfig) -> int:
    cap = int(math.ceil(group_size * cfg.top_k * cfg.moe_capacity_factor
                        / cfg.n_experts))
    return max(8, -(-cap // 8) * 8)  # multiple of 8 for TPU tiling


def init(key: jax.Array, cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ki, ko, ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": {"w": scale * jax.random.normal(kr, (d, e), jnp.float32)},
        "wg": scale * jax.random.normal(kg, (e, d, f), jnp.float32),
        "wi": scale * jax.random.normal(ki, (e, d, f), jnp.float32),
        "wo": (1.0 / np.sqrt(f)) * jax.random.normal(ko, (e, f, d), jnp.float32),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "wg": {"w": scale * jax.random.normal(k1, (d, fs), jnp.float32)},
            "wi": {"w": scale * jax.random.normal(k2, (d, fs), jnp.float32)},
            "wo": {"w": (1.0 / np.sqrt(fs)) * jax.random.normal(k3, (fs, d), jnp.float32)},
        }
    return p


def axes(cfg: ArchConfig) -> dict:
    ax = {
        "router": {"w": ("embed", None)},
        "wg": ("experts", "embed", "mlp"),
        "wi": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    if cfg.n_shared_experts:
        ax["shared"] = {
            "wg": {"w": ("embed", "mlp")},
            "wi": {"w": ("embed", "mlp")},
            "wo": {"w": ("mlp", "embed")},
        }
    return ax


def _route(p: dict, cfg: ArchConfig, x: jax.Array
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Router: x (G, T, D) -> (gates (G,T,k), experts (G,T,k), aux losses)."""
    logits = (x.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk:
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * sum_e f_e * P_e  (f = token fraction,
    # P = mean router prob); z-loss stabilizes the logits.
    e = cfg.n_experts
    onehot_top1 = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)
    f_e = jnp.mean(onehot_top1, axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    lb_loss = e * jnp.sum(f_e * p_e)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, idx, jnp.stack([lb_loss, z_loss])


def _dispatch_combine(cfg: ArchConfig, gates, idx, group_size: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Dense dispatch/combine tensors for one capacity-bucketed group batch.

    Returns (dispatch (G,T,E,C) bool-as-dtype, combine (G,T,E,C) f32).
    Position of a token in its expert's buffer = its rank among the group's
    tokens routed to that expert (per k-th choice, k-major so earlier
    choices claim slots first).
    """
    e, cap = cfg.n_experts, _capacity(group_size, cfg)
    disp = None
    comb = None
    # running per-expert fill count across the k choices
    fill = jnp.zeros(gates.shape[:-2] + (1, e), jnp.float32)  # (G, 1, E)
    for k in range(cfg.top_k):
        oh = jax.nn.one_hot(idx[..., k], e, dtype=jnp.float32)     # (G,T,E)
        pos = jnp.cumsum(oh, axis=-2) - oh + fill                  # (G,T,E)
        fill = fill + jnp.sum(oh, axis=-2, keepdims=True)
        within = pos < cap
        oh = oh * within
        pos_c = jax.nn.one_hot(jnp.sum(pos * oh, axis=-1).astype(jnp.int32),
                               cap, dtype=jnp.float32)             # (G,T,C)
        d_k = oh[..., :, None] * pos_c[..., None, :]               # (G,T,E,C)
        c_k = d_k * gates[..., k, None, None]
        disp = d_k if disp is None else disp + d_k
        comb = c_k if comb is None else comb + c_k
    return disp, comb


def apply(p: dict, cfg: ArchConfig, x: jax.Array
          ) -> tuple[jax.Array, jax.Array]:
    """MoE FFN.  x: (B, S, D) -> (out (B, S, D), aux (2,) losses)."""
    b, s, d = x.shape
    tokens = b * s
    group = min(cfg.moe_group_size, tokens)
    n_groups = tokens // group
    assert n_groups * group == tokens, (tokens, group)
    xg = x.reshape(n_groups, group, d)
    xg = sharding.constrain(xg, "batch", None, None)

    gates, idx, aux = _route(p, cfg, xg)
    disp, comb = _dispatch_combine(cfg, gates, idx, group)
    disp = disp.astype(x.dtype)

    # dispatch -> (G, E, C, D); experts shard over `experts` (EP): XLA turns
    # the G (batch-sharded) -> E (expert-sharded) layout change into the
    # canonical MoE all-to-all.
    xe = jnp.einsum("gtec,gtd->gecd", disp, xg)
    xe = sharding.constrain(xe, "batch", "experts", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    ye = sharding.constrain(ye, "batch", "experts", None, None)
    out = jnp.einsum("gtec,gecd->gtd", comb.astype(x.dtype), ye)
    out = out.reshape(b, s, d)

    if cfg.n_shared_experts:
        sh = p["shared"]
        hs = jax.nn.silu(nn.dense(sh["wg"], x, dtype=x.dtype)) * \
            nn.dense(sh["wi"], x, dtype=x.dtype)
        out = out + nn.dense(sh["wo"], hs, dtype=x.dtype)
    return out, aux

"""Whisper-style encoder-decoder backbone (whisper-tiny).

Per the brief the audio frontend is a STUB: `input_specs()` supplies
precomputed frame embeddings (B, S_src, D) — the output of whisper's conv1d
stack — and the encoder adds learned positions and runs bidirectional
attention.  The decoder is a causal transformer with cross-attention to the
encoder output; decode carries a self-attention KV cache plus the (static)
cross-attention KV computed once at prefill.

Whisper specifics honored: layernorm (pre-LN + final LN), GELU MLPs,
attention biases everywhere except wk, learned positional embeddings, no
RoPE, tied decoder embedding / output head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn, optim
from ..kernels import ops as kops
from ..parallel import sharding
from . import attention, blocks
from .config import ArchConfig


def _kind(cfg: ArchConfig) -> blocks.LayerKind:
    return blocks.LayerKind(None, "gelu_mlp", cfg.d_ff)


def _init_xattn(key: jax.Array, cfg: ArchConfig) -> dict:
    return attention.init(key, cfg)


def _stack(trees: list) -> dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init(key: jax.Array, cfg: ArchConfig) -> dict:
    kenc, kdec, kemb, kpos_e, kpos_d = jax.random.split(key, 5)
    kind = _kind(cfg)

    enc_blocks = []
    for i in range(cfg.encoder_layers):
        kenc, sub = jax.random.split(kenc)
        enc_blocks.append(blocks.init_block(sub, cfg, kind))

    dec_blocks = []
    for i in range(cfg.n_layers):
        kdec, s1, s2, s3 = jax.random.split(kdec, 4)
        blk = blocks.init_block(s1, cfg, kind)
        blk["xattn"] = _init_xattn(s2, cfg)
        blk["norm_x"] = blocks.init_norm(cfg)
        dec_blocks.append(blk)

    return {
        "enc_pos": {"table": 0.02 * jax.random.normal(
            kpos_e, (cfg.max_source_positions, cfg.d_model), jnp.float32)},
        "encoder": _stack(enc_blocks),
        "enc_final_norm": blocks.init_norm(cfg),
        "embed": nn.embedding_init(kemb, cfg.vocab, cfg.d_model),
        "dec_pos": {"table": 0.02 * jax.random.normal(
            kpos_d, (cfg.max_positions, cfg.d_model), jnp.float32)},
        "decoder": _stack(dec_blocks),
        "final_norm": blocks.init_norm(cfg),
    }


def param_axes(cfg: ArchConfig) -> dict:
    kind = _kind(cfg)
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(s, str) or s is None for s in x)
    prepend = lambda tree: jax.tree.map(lambda ax: (None,) + tuple(ax), tree,
                                        is_leaf=is_ax)
    dec_ax = blocks.block_axes(cfg, kind)
    dec_ax["xattn"] = attention.axes(cfg)
    dec_ax["norm_x"] = blocks.norm_axes(cfg)
    return {
        "enc_pos": {"table": (None, "embed")},
        "encoder": prepend(blocks.block_axes(cfg, kind)),
        "enc_final_norm": blocks.norm_axes(cfg),
        "embed": {"table": ("vocab", "embed")},
        "dec_pos": {"table": (None, "embed")},
        "decoder": prepend(dec_ax),
        "final_norm": blocks.norm_axes(cfg),
    }


def cache_axes(cfg: ArchConfig) -> dict:
    is_ax = lambda x: x is None or (isinstance(x, tuple) and all(
        isinstance(s, str) or s is None for s in x))
    prepend = lambda tree: jax.tree.map(
        lambda ax: (None,) + tuple(ax) if ax is not None else None, tree,
        is_leaf=is_ax)
    return {
        "self": prepend(attention.cache_axes()),
        "cross": prepend({"k": ("batch", "kv_heads", None, None),
                          "v": ("batch", "kv_heads", None, None)}),
    }


# --- encoder ---------------------------------------------------------------------
def encode(params: dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_src, D) stub frontend embeddings -> encoder states."""
    kind = _kind(cfg)
    s = frames.shape[1]
    x = frames.astype(cfg.dtype) + params["enc_pos"]["table"][:s].astype(cfg.dtype)
    x = sharding.constrain(x, "batch", "act_seq", None)

    def body(x, p_l):
        # bidirectional: full_attention with causal disabled via direct call
        h = blocks.apply_norm(p_l["norm1"], cfg, x)
        q, k, v = attention._qkv(p_l["mixer"], cfg, h)
        o = kops.attention(q, k, v, causal=False, window=None,
                           softcap=None, impl=cfg.attn_impl,
                           block_k=cfg.attn_block_k, unroll=cfg.unroll_scans)
        x = x + attention._out(p_l["mixer"], cfg, o)
        h2 = blocks.apply_norm(p_l["norm2"], cfg, x)
        f, _, _ = blocks.apply_ffn(p_l["ffn"], cfg, kind, h2)
        x = x + f
        return sharding.constrain(x, "batch", "act_seq", None), None

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(fn, x, params["encoder"])
    else:
        for i in range(cfg.encoder_layers):
            x, _ = fn(x, jax.tree.map(lambda a, i=i: a[i], params["encoder"]))
    return blocks.apply_norm(params["enc_final_norm"], cfg, x)


# --- decoder ---------------------------------------------------------------------
def _cross_kv(p: dict, cfg: ArchConfig, enc: jax.Array) -> dict:
    b, s, _ = enc.shape
    k = nn.dense(p["wk"], enc, dtype=enc.dtype).reshape(b, s, cfg.kv_heads, cfg.hd)
    v = nn.dense(p["wv"], enc, dtype=enc.dtype).reshape(b, s, cfg.kv_heads, cfg.hd)
    return {"k": jnp.swapaxes(k, 1, 2), "v": jnp.swapaxes(v, 1, 2)}


def _cross_attend(p: dict, cfg: ArchConfig, x: jax.Array, kv: dict) -> jax.Array:
    b, s, _ = x.shape
    q = nn.dense(p["wq"], x, dtype=x.dtype).reshape(b, s, cfg.n_heads, cfg.hd)
    q = jnp.swapaxes(q, 1, 2)
    o = kops.attention(q, kv["k"].astype(x.dtype), kv["v"].astype(x.dtype),
                       causal=False, window=None, softcap=None,
                       impl=cfg.attn_impl, block_k=cfg.attn_block_k,
                       unroll=cfg.unroll_scans)
    return attention._out(p, cfg, o)


def _decoder_block(p_l, cfg, kind, x, mode, cache):
    """Self-attn + cross-attn + FFN.  cache = {"self": kv, "cross": kv}."""
    h = blocks.apply_norm(p_l["norm1"], cfg, x)
    if mode == "train":
        a = attention.full_attention(p_l["mixer"], cfg, h, window=None)
        new_self = None
    elif mode == "prefill":
        a, new_self = attention.prefill_attention(p_l["mixer"], cfg, h,
                                                  cache["self"], window=None)
    else:
        a, new_self = attention.decode_attention(
            p_l["mixer"], cfg, h, cache["self"], window=None,
            combine=cfg.decode_combine)
    x = x + a
    hx = blocks.apply_norm(p_l["norm_x"], cfg, x)
    x = x + _cross_attend(p_l["xattn"], cfg, hx, cache["cross"])
    h2 = blocks.apply_norm(p_l["norm2"], cfg, x)
    f, _, _ = blocks.apply_ffn(p_l["ffn"], cfg, kind, h2)
    x = x + f
    x = sharding.constrain(x, "batch", "act_seq", None)
    new_cache = None if new_self is None else {"self": new_self,
                                               "cross": cache["cross"]}
    return x, new_cache


def decode_hidden(params: dict, cfg: ArchConfig, tokens: jax.Array,
                  positions: jax.Array, caches: dict, mode: str
                  ) -> tuple[jax.Array, dict | None]:
    kind = _kind(cfg)
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.dtype)
    x = x + params["dec_pos"]["table"][positions].astype(cfg.dtype)
    x = sharding.constrain(x, "batch", "act_seq", None)

    def body(x, scanned):
        p_l, c_l = scanned
        x, nc = _decoder_block(p_l, cfg, kind, x, mode, c_l)
        return x, nc

    fn = (jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
          if (cfg.remat and mode == "train") else body)
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(fn, x, (params["decoder"], caches))
    else:
        ncs = []
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda a, i=i: a[i], (params["decoder"], caches))
            x, nc = fn(x, sl)
            ncs.append(nc)
        new_caches = (None if ncs and ncs[0] is None
                      else jax.tree.map(lambda *xs: jnp.stack(xs), *ncs))
    return x, new_caches


# --- losses / steps ----------------------------------------------------------------
def lm_loss(params: dict, cfg: ArchConfig, batch: dict) -> tuple[jax.Array, dict]:
    """batch: {"frames" (B,S_src,D), "tokens" (B,T), "labels" (B,T)}."""
    enc = encode(params, cfg, batch["frames"])
    b, t = batch["tokens"].shape
    cross = jax.vmap(lambda p_l: _cross_kv(p_l["xattn"], cfg, enc))(
        params["decoder"])
    kind = _kind(cfg)
    x = jnp.take(params["embed"]["table"], batch["tokens"], axis=0).astype(cfg.dtype)
    x = x + params["dec_pos"]["table"][:t][None].astype(cfg.dtype)
    x = sharding.constrain(x, "batch", "act_seq", None)

    def body(x, scanned):
        p_l, cross_l = scanned
        x, _ = _decoder_block(p_l, cfg, kind, x, "train",
                              {"self": None, "cross": cross_l})
        return x, None

    fn = (jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
          if cfg.remat else body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(fn, x, (params["decoder"], cross))
    else:
        for i in range(cfg.n_layers):
            x, _ = fn(x, jax.tree.map(lambda a, i=i: a[i],
                                      (params["decoder"], cross)))

    from . import lm as lm_mod
    mask = batch.get("mask", jnp.ones_like(batch["labels"], jnp.float32))
    # tied head (whisper ties embed/head): reuse the lm chunked CE; the shim
    # params carry exactly what logits_for needs.
    shim = {"final_norm": params["final_norm"], "embed": params["embed"]}
    nll, count = lm_mod.chunked_ce(shim, cfg, x, batch["labels"],
                                   mask.astype(jnp.float32))
    ce = nll / jnp.maximum(count, 1.0)
    return ce, {"loss": ce, "ce": ce, "tokens": count}


def train_step(params: dict, opt_state, batch: dict, cfg: ArchConfig,
               adam_cfg: optim.AdamConfig | None = None):
    adam_cfg = adam_cfg or optim.AdamConfig(lr=3e-4, grad_clip=1.0)
    (loss, metrics), grads = jax.value_and_grad(lm_loss, has_aux=True)(
        params, cfg, batch)
    metrics["grad_norm"] = optim.global_norm(grads)
    params, opt_state = optim.adam_update(adam_cfg, params, grads, opt_state)
    return params, opt_state, metrics


def prefill(params: dict, cfg: ArchConfig, frames: jax.Array,
            tokens: jax.Array, cache_len: int | None = None,
            cache_dtype=jnp.bfloat16) -> tuple[jax.Array, dict]:
    """Encode + run the prompt through the decoder, building caches."""
    b, t = tokens.shape
    enc = encode(params, cfg, frames)
    cross = jax.vmap(lambda p_l: _cross_kv(p_l["xattn"], cfg, enc))(
        params["decoder"])
    self_c = attention.init_cache(cfg, b, cache_len or t, window=None,
                                  dtype=cache_dtype)
    self_stack = jax.tree.map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), self_c)
    caches = {"self": self_stack, "cross": cross}
    positions = jnp.arange(t)[None]
    x, new_caches = decode_hidden(params, cfg, tokens, positions, caches,
                                  "prefill")
    h = blocks.apply_norm(params["final_norm"], cfg, x[:, -1:])
    w = params["embed"]["table"].T.astype(h.dtype)
    logits = (h @ w).astype(jnp.float32)[:, 0]
    return logits, new_caches


def decode_step(params: dict, cfg: ArchConfig, token: jax.Array, caches: dict
                ) -> tuple[jax.Array, dict]:
    pos = caches["self"]["pos"][0][None, None]  # shared across layers
    x, new_caches = decode_hidden(params, cfg, token[:, None],
                                  pos.astype(jnp.int32), caches, "decode")
    h = blocks.apply_norm(params["final_norm"], cfg, x)
    w = params["embed"]["table"].T.astype(h.dtype)
    logits = (h @ w).astype(jnp.float32)[:, 0]
    return logits, new_caches

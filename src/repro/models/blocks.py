"""Transformer block assembly: norms + mixer + FFN for every assigned arch.

A block is `(params, cfg, layer_kind)` plus a mode:

    mode="train"    full-sequence, no cache
    mode="prefill"  full-sequence, builds cache
    mode="decode"   single token against cache

`layer_kind` carries the static per-layer choices: attention window
(gemma-2 local/global alternation, hymba/danube SWA) and FFN flavor
(deepseek/moonshot dense-prefix layers).  Cache pytrees mirror the mixer:
attention layers carry a KV dict, SSM/RWKV layers a state dict, hybrid
layers both.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn
from ..parallel import sharding
from . import attention, moe, rwkv, ssm
from .config import ArchConfig


@dataclasses.dataclass(frozen=True)
class LayerKind:
    window: int | None          # None -> full attention
    ffn: str                    # swiglu | geglu | gelu_mlp | moe | rwkv_cmix
    d_ff: int


def layer_kind(cfg: ArchConfig, i: int) -> LayerKind:
    window = None if cfg.layer_is_global(i) else cfg.window
    if cfg.ffn == "moe" and i < cfg.first_dense_layers:
        return LayerKind(window, "swiglu", cfg.d_ff_dense or cfg.d_ff)
    return LayerKind(window, cfg.ffn, cfg.d_ff)


# --- dense FFNs -----------------------------------------------------------------
def init_ffn(key: jax.Array, cfg: ArchConfig, kind: LayerKind) -> dict:
    d, f = cfg.d_model, kind.d_ff
    if kind.ffn == "moe":
        return moe.init(key, cfg)
    if kind.ffn == "rwkv_cmix":
        return rwkv.init_channel_mix(key, cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(d)
    p = {
        "wi": nn.dense_init(k1, d, f, bias=cfg.mlp_bias,
                            w_init=lambda k, sh: s * jax.random.normal(k, sh, jnp.float32)),
        "wo": nn.dense_init(k2, f, d, bias=cfg.mlp_bias,
                            w_init=lambda k, sh: (1.0 / np.sqrt(f)) * jax.random.normal(k, sh, jnp.float32)),
    }
    if kind.ffn in ("swiglu", "geglu"):
        p["wg"] = nn.dense_init(k3, d, f, bias=cfg.mlp_bias,
                                w_init=lambda k, sh: s * jax.random.normal(k, sh, jnp.float32))
    return p


def ffn_axes(cfg: ArchConfig, kind: LayerKind) -> dict:
    if kind.ffn == "moe":
        return moe.axes(cfg)
    if kind.ffn == "rwkv_cmix":
        return rwkv.channel_mix_axes(cfg)
    def wb(ax):
        return {"w": ax, "b": (ax[-1],)} if cfg.mlp_bias else {"w": ax}
    p = {"wi": wb(("embed", "mlp")), "wo": wb(("mlp", "embed"))}
    if kind.ffn in ("swiglu", "geglu"):
        p["wg"] = wb(("embed", "mlp"))
    return p


def apply_ffn(p: dict, cfg: ArchConfig, kind: LayerKind, x: jax.Array,
              state: jax.Array | None = None):
    """-> (out, aux (2,), new_state_or_None)."""
    zero_aux = jnp.zeros((2,), jnp.float32)
    if kind.ffn == "moe":
        out, aux = moe.apply(p, cfg, x)
        return out, aux, None
    if kind.ffn == "rwkv_cmix":
        out, shift = rwkv.channel_mix(p, cfg, x, state)
        return out, zero_aux, shift
    h = nn.dense(p["wi"], x, dtype=x.dtype)
    if kind.ffn == "swiglu":
        h = jax.nn.silu(nn.dense(p["wg"], x, dtype=x.dtype)) * h
    elif kind.ffn == "geglu":
        h = jax.nn.gelu(nn.dense(p["wg"], x, dtype=x.dtype)) * h
    else:  # gelu_mlp
        h = jax.nn.gelu(h)
    h = sharding.constrain(h, "batch", None, "mlp")
    return nn.dense(p["wo"], h, dtype=x.dtype), zero_aux, None


# --- norms ------------------------------------------------------------------------
def init_norm(cfg: ArchConfig) -> dict:
    if cfg.norm == "layernorm":
        return nn.layernorm_init(cfg.d_model)
    if cfg.norm == "layernorm_nobias":  # command-r
        return nn.layernorm_init(cfg.d_model, bias=False)
    return nn.rmsnorm_init(cfg.d_model)


def norm_axes(cfg: ArchConfig) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": ("embed",), "bias": ("embed",)}
    return {"scale": ("embed",)}


def apply_norm(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.norm.startswith("layernorm"):
        return nn.layernorm(p, x)
    return nn.rmsnorm(p, x, scale_plus_one=cfg.norm_scale_plus_one)


# --- block ---------------------------------------------------------------------
def init_block(key: jax.Array, cfg: ArchConfig, kind: LayerKind) -> dict:
    km, kf, _ = jax.random.split(key, 3)
    p: dict = {"norm1": init_norm(cfg), "ffn": init_ffn(kf, cfg, kind)}
    if cfg.mixer == "rwkv":
        p["mixer"] = rwkv.init_time_mix(km, cfg)
    elif cfg.mixer == "attn+mamba":
        ka, ks = jax.random.split(km)
        p["mixer"] = {"attn": attention.init(ka, cfg), "ssm": ssm.init(ks, cfg)}
    else:
        p["mixer"] = attention.init(km, cfg)
    if not cfg.parallel_block:
        p["norm2"] = init_norm(cfg)
    if cfg.post_norms:
        p["post_norm1"] = init_norm(cfg)
        p["post_norm2"] = init_norm(cfg)
    return p


def block_axes(cfg: ArchConfig, kind: LayerKind) -> dict:
    ax: dict = {"norm1": norm_axes(cfg), "ffn": ffn_axes(cfg, kind)}
    if cfg.mixer == "rwkv":
        ax["mixer"] = rwkv.time_mix_axes(cfg)
    elif cfg.mixer == "attn+mamba":
        ax["mixer"] = {"attn": attention.axes(cfg), "ssm": ssm.axes(cfg)}
    else:
        ax["mixer"] = attention.axes(cfg)
    if not cfg.parallel_block:
        ax["norm2"] = norm_axes(cfg)
    if cfg.post_norms:
        ax["post_norm1"] = norm_axes(cfg)
        ax["post_norm2"] = norm_axes(cfg)
    return ax


def init_block_cache(cfg: ArchConfig, kind: LayerKind, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> dict:
    """Decode/prefill cache for one block (empty)."""
    cache: dict = {}
    if cfg.mixer == "rwkv":
        # one dict carries wkv state + time-mix and channel-mix shifts
        cache["mixer"] = rwkv.init_state(cfg, batch, dtype)
        return cache
    if cfg.mixer == "attn+mamba":
        cache["mixer"] = {
            "attn": attention.init_cache(cfg, batch, max_len,
                                         window=kind.window, dtype=dtype),
            "ssm": ssm.init_state(cfg, batch, dtype),
        }
        return cache
    cache["mixer"] = attention.init_cache(cfg, batch, max_len,
                                          window=kind.window, dtype=dtype)
    return cache


def block_cache_axes(cfg: ArchConfig) -> dict:
    if cfg.mixer == "rwkv":
        return {"mixer": rwkv.state_axes()}
    if cfg.mixer == "attn+mamba":
        return {"mixer": {"attn": attention.cache_axes(),
                          "ssm": ssm.state_axes()}}
    return {"mixer": attention.cache_axes()}


def _mix(p: dict, cfg: ArchConfig, kind: LayerKind, x: jax.Array,
         mode: str, cache: dict | None):
    """Apply the mixer.  Returns (out, new_cache_or_None)."""
    if cfg.mixer == "rwkv":
        st = cache["mixer"] if cache else None
        out, wkv_s, shift = rwkv.time_mix(
            p, cfg, x,
            st["wkv"] if st else None, st["shift_t"] if st else None)
        if mode == "train":
            return out, None
        new = {"wkv": wkv_s, "shift_t": shift,
               "shift_c": st["shift_c"] if st else
               jnp.zeros((x.shape[0], cfg.d_model), x.dtype)}
        return out, new

    if cfg.mixer == "attn+mamba":
        ca = cache["mixer"] if cache else None
        if mode == "train":
            a_out = attention.full_attention(p["attn"], cfg, x, window=kind.window)
            s_out, s_state = ssm.apply_seq(p["ssm"], cfg, x, None)
            return 0.5 * (a_out + s_out), None
        if mode == "prefill":
            a_out, a_cache = attention.prefill_attention(
                p["attn"], cfg, x, ca["attn"], window=kind.window)
            s_out, s_state = ssm.apply_seq(p["ssm"], cfg, x, None)
        else:
            a_out, a_cache = attention.decode_attention(
                p["attn"], cfg, x, ca["attn"], window=kind.window,
                combine=cfg.decode_combine)
            s_out, s_state = ssm.apply_step(p["ssm"], cfg, x, ca["ssm"])
        return 0.5 * (a_out + s_out), {"attn": a_cache, "ssm": s_state}

    # pure attention
    ca = cache["mixer"] if cache else None
    if mode == "train":
        return attention.full_attention(p, cfg, x, window=kind.window), None
    if mode == "prefill":
        out, new = attention.prefill_attention(p, cfg, x, ca, window=kind.window)
    else:
        out, new = attention.decode_attention(p, cfg, x, ca, window=kind.window,
                                              combine=cfg.decode_combine)
    return out, new


def apply_block(p: dict, cfg: ArchConfig, kind: LayerKind, x: jax.Array,
                mode: str = "train", cache: dict | None = None):
    """-> (x, aux (2,), new_cache_or_None)."""
    h = apply_norm(p["norm1"], cfg, x)

    if cfg.parallel_block:  # command-r: attn & ffn read the same norm
        m_out, m_cache = _mix(p["mixer"], cfg, kind, h, mode, cache)
        f_out, aux, f_state = apply_ffn(p["ffn"], cfg, kind, h)
        x = x + m_out + f_out
        new_cache = None if m_cache is None else {"mixer": m_cache}
        return x, aux, new_cache

    m_out, m_cache = _mix(p["mixer"], cfg, kind, h, mode, cache)
    if cfg.post_norms:
        m_out = apply_norm(p["post_norm1"], cfg, m_out)
    x = x + m_out
    x = sharding.constrain(x, "batch", "act_seq", None)

    h2 = apply_norm(p["norm2"], cfg, x)
    ffn_state_in = (cache["mixer"]["shift_c"]
                    if (cache and cfg.mixer == "rwkv") else None)
    f_out, aux, f_state = apply_ffn(p["ffn"], cfg, kind, h2, ffn_state_in)
    if cfg.post_norms:
        f_out = apply_norm(p["post_norm2"], cfg, f_out)
    x = x + f_out
    x = sharding.constrain(x, "batch", "act_seq", None)

    if m_cache is None:
        return x, aux, None
    if cfg.mixer == "rwkv" and f_state is not None:
        m_cache = dict(m_cache, shift_c=f_state)
    return x, aux, {"mixer": m_cache}

"""Uniform model API over decoder-only (lm.py) and enc-dec (encdec.py)
architectures — what the launcher, dry-run and benchmarks program against.

Every function takes the static ArchConfig and dispatches on family.  Batch
dicts are produced by `data.synthetic` / `launch.specs.input_specs` with the
same keys used here.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .. import optim
from . import encdec, lm
from .config import ArchConfig


def init(key: jax.Array, cfg: ArchConfig) -> dict:
    return encdec.init(key, cfg) if cfg.is_encdec else lm.init(key, cfg)


def param_axes(cfg: ArchConfig) -> dict:
    return encdec.param_axes(cfg) if cfg.is_encdec else lm.param_axes(cfg)


def abstract_params(cfg: ArchConfig) -> Any:
    """ShapeDtypeStruct pytree of the parameters (no allocation).

    Honors cfg.param_dtype: training keeps f32 masters; serving cells lower
    against bf16 weights (the deployed artifact)."""
    def build():
        p = init(jax.random.PRNGKey(0), cfg)
        if cfg.param_dtype != "float32":
            p = jax.tree.map(lambda x: x.astype(cfg.param_dtype), p)
        return p
    return jax.eval_shape(build)


def loss(params: dict, cfg: ArchConfig, batch: dict):
    return (encdec.lm_loss if cfg.is_encdec else lm.lm_loss)(params, cfg, batch)


def train_step(params: dict, opt_state, batch: dict, cfg: ArchConfig,
               adam_cfg: optim.AdamConfig | None = None):
    fn = encdec.train_step if cfg.is_encdec else lm.train_step
    return fn(params, opt_state, batch, cfg, adam_cfg)


def prefill(params: dict, cfg: ArchConfig, batch: dict,
            cache_len: int | None = None, cache_dtype=jnp.bfloat16):
    """batch: {"tokens", optional "patches"/"frames"} -> (logits, caches)."""
    if cfg.is_encdec:
        return encdec.prefill(params, cfg, batch["frames"], batch["tokens"],
                              cache_len=cache_len, cache_dtype=cache_dtype)
    return lm.prefill(params, cfg, batch["tokens"],
                      patches=batch.get("patches"), cache_len=cache_len,
                      cache_dtype=cache_dtype)


def decode_step(params: dict, cfg: ArchConfig, token: jax.Array, caches: dict):
    fn = encdec.decode_step if cfg.is_encdec else lm.decode_step
    return fn(params, cfg, token, caches)


def serve_step(params: dict, cfg: ArchConfig, token: jax.Array, caches: dict):
    """Alias used by the dry-run cells (one new token against the caches)."""
    return decode_step(params, cfg, token, caches)


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    if cfg.is_encdec:
        raise ValueError("enc-dec caches are built by prefill (cross-KV "
                         "depends on the encoder output)")
    return lm.init_caches(cfg, batch, max_len, dtype)


def cache_axes(cfg: ArchConfig) -> dict:
    return encdec.cache_axes(cfg) if cfg.is_encdec else lm.cache_axes(cfg)


def abstract_caches(cfg: ArchConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct pytree of the decode caches (no allocation)."""
    if cfg.is_encdec:
        def build():
            self_c = jax.tree.map(
                lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype),
                _enc_self_cache(cfg, batch, max_len, dtype))
            cross = {
                "k": jnp.zeros((cfg.n_layers, batch, cfg.kv_heads,
                                cfg.max_source_positions, cfg.hd), dtype),
                "v": jnp.zeros((cfg.n_layers, batch, cfg.kv_heads,
                                cfg.max_source_positions, cfg.hd), dtype),
            }
            return {"self": self_c, "cross": cross}
        return jax.eval_shape(build)
    return jax.eval_shape(lambda: lm.init_caches(cfg, batch, max_len, dtype))


def _enc_self_cache(cfg, batch, max_len, dtype):
    from . import attention
    return attention.init_cache(cfg, batch, max_len, window=None, dtype=dtype)

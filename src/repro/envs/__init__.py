"""Solver-agnostic RL environments: the Env protocol and scenario registry.

    from repro import envs

    env = envs.make("hit_les_reduced")      # or "burgers_reduced", ...
    print(envs.registered())

Every scenario implements the same pure `reset/step/observe` contract with
declarative obs/action specs (envs/base.py) — including a NAMED observation
channel tuple (`ObsSpec.channel_specs`) — so the whole training stack —
policy heads, rollout scan, fleet orchestration, PPO — is generic over the
physics (the paper's "easy integration of various HPC solvers" modularity
claim, jit-native).  See docs/adding_an_environment.md for the
scenario-authoring guide.
"""
from .base import (ActionSpec, ChannelSpec, Env, EnvState, ObsSpec,
                   StepResult, as_env, init_state, velocity_channels)
from .registry import make, register, registered

# Importing the scenario modules populates the registry.
from . import burgers, channel, hit_les  # noqa: F401  (registration side effects)
from .burgers import BurgersEnv
from .channel import ChannelEnv
from .hit_les import HITLESEnv

__all__ = [
    "ActionSpec",
    "BurgersEnv",
    "ChannelEnv",
    "ChannelSpec",
    "Env",
    "EnvState",
    "HITLESEnv",
    "ObsSpec",
    "StepResult",
    "as_env",
    "init_state",
    "make",
    "register",
    "registered",
    "velocity_channels",
]

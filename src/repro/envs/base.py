"""The solver-agnostic environment contract.

The paper's framework ("Relexi is built with modularity in mind and allows
easy integration of various HPC solvers") couples ANY MPI solver to the RL
loop through a thin state/action/reward exchange.  This module is the
jit-native formulation of that boundary: an environment is a *hashable,
static* object whose methods are pure array programs, so the whole fleet —
any scenario — compiles into one XLA program (jit / vmap / shard_map pass
straight through).

Layout conventions shared by every environment:

  * `EnvState.u` is a single conservative/nodal state array whose leading
    axes may carry an environment batch; `initial_state_bank` returns a
    stack of such arrays with the bank axis first.
  * Observations are element-local: shape (..., E, *spatial, C) with E the
    number of DG elements, `spatial` the per-element node grid (1-D or 3-D)
    and C the channel count — declared by `ObsSpec`.
  * Actions are per-element scalars (..., E) bounded to
    [`ActionSpec.low`, `ActionSpec.high`].

`core/policy.py` builds its actor/critic heads from these specs alone;
`core/rollout.py` scans `step` over any `Env`; `core/orchestrator.py` only
adds fleet sharding + the initial-state bank.  Nothing in `core/` imports a
concrete solver.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


class EnvState(NamedTuple):
    """Carried MDP state: solver field + RL step counter."""

    u: jax.Array          # solver state; leading axes may be a batch
    t_step: jax.Array     # RL step counter (int32, scalar or (B,))


class StepResult(NamedTuple):
    state: EnvState
    obs: jax.Array
    reward: jax.Array
    done: jax.Array


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Declarative per-environment observation layout (..., E, *spatial, C)."""

    n_elements: int                 # E: number of DG elements
    spatial: tuple[int, ...]        # per-element node grid, e.g. (n, n, n) or (n,)
    channels: int                   # C
    # Physical divisor the env ALREADY applied inside observe() (e.g. u_rms),
    # declared so consumers can un-normalize for diagnostics.  The training
    # stack never re-applies it — observations arrive O(1) by contract.
    scale: float = 1.0

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.n_elements, *self.spatial, self.channels)

    @property
    def ndim_spatial(self) -> int:
        return len(self.spatial)


@dataclasses.dataclass(frozen=True)
class ActionSpec:
    """Per-element bounded scalar action (..., E) in [low, high]."""

    n_elements: int
    low: float = 0.0
    high: float = 1.0

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.n_elements,)


@runtime_checkable
class Env(Protocol):
    """The contract every registered scenario implements.

    Implementations must be hashable (frozen dataclasses over scalar
    configs) — they are closed over by jit as static values — and every
    method must be a pure function of its array arguments.
    """

    @property
    def obs_spec(self) -> ObsSpec: ...

    @property
    def action_spec(self) -> ActionSpec: ...

    @property
    def n_actions(self) -> int:
        """Episode horizon T (fixed-length episodes, as in the paper)."""
        ...

    def initial_state_bank(self, key: jax.Array, n: int) -> jax.Array:
        """(n, *state_shape) device-resident bank of initial solver states."""
        ...

    def reset_from_bank(self, bank: jax.Array, index: jax.Array
                        ) -> tuple[EnvState, jax.Array]: ...

    def observe(self, state: EnvState) -> jax.Array: ...

    def step(self, state: EnvState, action: jax.Array) -> StepResult:
        """One MDP transition; deterministic given (state, action)."""
        ...


def init_state(u0: jax.Array, batch_shape: tuple[int, ...] = ()) -> EnvState:
    """Wrap bank rows (or a single state) into a fresh EnvState at t=0."""
    return EnvState(u=u0, t_step=jnp.zeros(batch_shape, jnp.int32))


def as_env(env_or_cfg) -> Env:
    """Coerce legacy `HITConfig` values to the Env protocol.

    Pre-refactor call sites passed a raw `HITConfig` into the orchestrator /
    runner; keep them working by wrapping it in the HIT-LES adapter.
    """
    from ..cfd.solver import HITConfig
    if isinstance(env_or_cfg, HITConfig):
        from .hit_les import HITLESEnv
        return HITLESEnv(cfg=env_or_cfg)
    return env_or_cfg

"""The solver-agnostic environment contract.

The paper's framework ("Relexi is built with modularity in mind and allows
easy integration of various HPC solvers") couples ANY MPI solver to the RL
loop through a thin state/action/reward exchange.  This module is the
jit-native formulation of that boundary: an environment is a *hashable,
static* object whose methods are pure array programs, so the whole fleet —
any scenario — compiles into one XLA program (jit / vmap / shard_map pass
straight through).

Layout conventions shared by every environment:

  * `EnvState.u` is a single conservative/nodal state array whose leading
    axes may carry an environment batch; `initial_state_bank` returns a
    stack of such arrays with the bank axis first.
  * Observations are element-local: shape (..., E, *spatial, C) with E the
    number of DG elements and `spatial` the per-element node grid (1-D or
    3-D).  The trailing axis is NOT a bare count: every channel is declared
    by name in `ObsSpec.channel_specs` (a tuple of `ChannelSpec`), in the
    order `observe()` stacks them, each carrying the physical normalization
    scale the env already divided by.  `ObsSpec.channels` is the derived
    count.
  * Each observation channel arrives O(1): `observe()` divides channel c by
    `channel_specs[c].scale` (e.g. velocities by u_rms, wall pressure by
    the wall shear stress).  The training stack never re-applies the scale;
    it may apply the declared per-channel `gain` at the policy input
    (see core/policy.py).
  * Actions are per-element scalars (..., E) bounded to
    [`ActionSpec.low`, `ActionSpec.high`].

`core/policy.py` builds its actor/critic heads from these specs alone;
`core/rollout.py` scans `step` over any `Env`; `core/orchestrator.py` only
adds fleet sharding + the initial-state bank.  Nothing in `core/` imports a
concrete solver.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


class EnvState(NamedTuple):
    """Carried MDP state: solver field + RL step counter."""

    u: jax.Array          # solver state; leading axes may be a batch
    t_step: jax.Array     # RL step counter (int32, scalar or (B,))


class StepResult(NamedTuple):
    state: EnvState
    obs: jax.Array
    reward: jax.Array
    done: jax.Array


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """One named observation channel.

    `scale` is the physical divisor the env ALREADY applied to this channel
    inside `observe()` (e.g. u_rms for velocities, rho u_tau^2 for wall
    pressure), declared so consumers can un-normalize for diagnostics.  The
    training stack never re-applies it — channels arrive O(1) by contract.
    `gain` is an optional policy-input multiplier for channels whose O(1)
    normalization still leaves them systematically small/large next to
    their siblings; `core/policy.py` applies it at the trunk input.
    """

    name: str
    scale: float = 1.0
    gain: float = 1.0


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Declarative per-environment observation layout (..., E, *spatial, C).

    The trailing axis is a tuple of NAMED channels, in the order `observe()`
    stacks them; the legacy `channels` count and uniform `scale` survive as
    derived properties.

    >>> spec = ObsSpec(n_elements=8, spatial=(4, 4, 4),
    ...                channel_specs=(ChannelSpec("u_x", scale=2.0),
    ...                               ChannelSpec("u_y", scale=2.0),
    ...                               ChannelSpec("u_z", scale=2.0)))
    >>> spec.channels
    3
    >>> spec.channel_names
    ('u_x', 'u_y', 'u_z')
    >>> spec.scale
    2.0
    >>> spec.shape
    (8, 4, 4, 4, 3)
    """

    n_elements: int                 # E: number of DG elements
    spatial: tuple[int, ...]        # per-element node grid, e.g. (n, n, n) or (n,)
    channel_specs: tuple[ChannelSpec, ...]

    def __post_init__(self):
        names = self.channel_names
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate channel names: {names}")

    @property
    def channels(self) -> int:
        """C — derived from the declared channel tuple (legacy accessor)."""
        return len(self.channel_specs)

    @property
    def channel_names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.channel_specs)

    @property
    def channel_scales(self) -> tuple[float, ...]:
        return tuple(c.scale for c in self.channel_specs)

    @property
    def channel_gains(self) -> tuple[float, ...]:
        return tuple(c.gain for c in self.channel_specs)

    @property
    def scale(self) -> float:
        """Legacy uniform scale; defined only when all channels agree."""
        scales = set(self.channel_scales)
        if len(scales) != 1:
            raise ValueError(
                f"mixed per-channel scales {self.channel_scales}; "
                "use channel_scales instead of the legacy uniform scale")
        return next(iter(scales))

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.n_elements, *self.spatial, self.channels)

    @property
    def ndim_spatial(self) -> int:
        return len(self.spatial)

    def validate(self, obs) -> None:
        """Raise if `obs` does not conform to this spec (trailing axes;
        name uniqueness is already enforced at construction)."""
        got = tuple(obs.shape[-(len(self.shape)):])
        if got != self.shape:
            raise ValueError(f"observation trailing shape {got} != declared "
                             f"{self.shape} (channels {self.channel_names})")


def velocity_channels(ndim: int, scale: float) -> tuple[ChannelSpec, ...]:
    """The standard velocity channel block: ('u_x'[, 'u_y', 'u_z'])."""
    return tuple(ChannelSpec(f"u_{ax}", scale=scale)
                 for ax in ("x", "y", "z")[:ndim])


@dataclasses.dataclass(frozen=True)
class ActionSpec:
    """Per-element bounded scalar action (..., E) in [low, high]."""

    n_elements: int
    low: float = 0.0
    high: float = 1.0

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.n_elements,)


@runtime_checkable
class Env(Protocol):
    """The contract every registered scenario implements.

    Implementations must be hashable (frozen dataclasses over scalar
    configs) — they are closed over by jit as static values — and every
    method must be a pure function of its array arguments.
    """

    @property
    def obs_spec(self) -> ObsSpec: ...

    @property
    def action_spec(self) -> ActionSpec: ...

    @property
    def n_actions(self) -> int:
        """Episode horizon T (fixed-length episodes, as in the paper)."""
        ...

    def initial_state_bank(self, key: jax.Array, n: int) -> jax.Array:
        """(n, *state_shape) device-resident bank of initial solver states."""
        ...

    def reset_from_bank(self, bank: jax.Array, index: jax.Array
                        ) -> tuple[EnvState, jax.Array]: ...

    def observe(self, state: EnvState) -> jax.Array: ...

    def step(self, state: EnvState, action: jax.Array) -> StepResult:
        """One MDP transition; deterministic given (state, action)."""
        ...


def init_state(u0: jax.Array, batch_shape: tuple[int, ...] = ()) -> EnvState:
    """Wrap bank rows (or a single state) into a fresh EnvState at t=0."""
    return EnvState(u=u0, t_step=jnp.zeros(batch_shape, jnp.int32))


def as_env(env_or_cfg) -> Env:
    """Coerce legacy `HITConfig` values to the Env protocol.

    Pre-refactor call sites passed a raw `HITConfig` into the orchestrator /
    runner; keep them working by wrapping it in the HIT-LES adapter.
    """
    from ..cfd.solver import HITConfig
    if isinstance(env_or_cfg, HITConfig):
        from .hit_les import HITLESEnv
        return HITLESEnv(cfg=env_or_cfg)
    return env_or_cfg

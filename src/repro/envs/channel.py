"""Wall-modeled channel-flow control scenario on the generic Env protocol.

The third registered scenario, and the first NON-PERIODIC one: it proves the
Env protocol carries an anisotropic state layout (Kx != Ky != Kz elements,
unequal box lengths) and weak wall boundary conditions end to end through
the unchanged orchestrator/rollout/runner.  See cfd/channel.py for the
physics (mixed-BC DGSEM, Reichardt wall model, pressure-gradient forcing).

Obs    : the two layers of wall-adjacent elements.  Channels are declared
         by name in `ObsSpec.channel_specs`:
           * base `channel_wm`: ('u_x', 'u_y', 'u_z') velocity nodes,
             normalized by u_bulk — (2*Kx*Kz, n, n, n, 3);
           * `channel_wm_p` (obs_pressure=True): the same three plus
             'p_wall', the near-wall static-pressure fluctuation p - p0
             normalized by the wall shear stress rho u_tau^2 —
             (2*Kx*Kz, n, n, n, 4);
           * `channel_wm_t` (obs_temperature=True): the same three plus
             'T_wall', the near-wall temperature fluctuation T - T0
             normalized by the friction-temperature scale u_tau^2/cp;
           * `channel_wm_hre`: the base observation at a higher-Re_tau
             configuration (Re_tau ~ 90, scaled Reichardt parameters).
         Top-wall elements are mirrored (y node axis flipped, v_y negated)
         so both walls present the same orientation to the shared policy
         trunk — "away from the wall" is always increasing node index.
Action : per-wall-element wall-stress scaling a in [0, a_max]; a = 1
         applies the equilibrium wall model as-is (the static baseline).
Reward : 2 exp(-l/alpha) - 1 with l the quadrature-weighted relative L2
         error of the x-z mean velocity profile against the Reichardt
         log-law reference — the profile analog of the paper's spectral
         reward.

Registry overrides reach every `ChannelConfig` field, e.g.
`envs.make("channel_wm", precision="bf16")` advances the flow state in
bfloat16 (obs/reward/PPO stay float32 — see ChannelConfig.precision).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..cfd import channel, spectra
from ..cfd.channel import ChannelConfig
from .base import (ActionSpec, ChannelSpec, EnvState, ObsSpec, StepResult,
                   velocity_channels)
from .registry import register


@dataclasses.dataclass(frozen=True)
class ChannelEnv:
    """Plane-channel WMLES, per-wall-element stress-scaling control.

    With `obs_pressure=True` the observation gains a fourth named channel:
    the near-wall pressure fluctuation normalized by rho u_tau^2 (the RL
    analog of HydroGym/drlfoam-style multi-field probes).  Its declared
    policy-input gain of 0.5 re-balances the channel against the O(1)
    velocities (p'_rms ~ 2-3 tau_w in channel flow).

    With `obs_temperature=True` the observation instead/additionally gains
    the near-wall temperature fluctuation T - T0 normalized by the
    friction-temperature scale u_tau^2/cp (`ChannelConfig.t_tau`) — the
    thermal sibling of the pressure channel (ROADMAP follow-on from the
    named-channel refactor).  Channel order is always
    velocities [, p_wall][, T_wall].
    """

    cfg: ChannelConfig
    obs_pressure: bool = False
    obs_temperature: bool = False

    @property
    def obs_spec(self) -> ObsSpec:
        n = self.cfg.n
        chans = velocity_channels(3, self.cfg.u_bulk)
        if self.obs_pressure:
            chans = chans + (ChannelSpec("p_wall", scale=self.cfg.tau_wall,
                                         gain=0.5),)
        if self.obs_temperature:
            chans = chans + (ChannelSpec("T_wall", scale=self.cfg.t_tau,
                                         gain=0.5),)
        return ObsSpec(n_elements=self.cfg.n_wall_elements,
                       spatial=(n, n, n), channel_specs=chans)

    @property
    def action_spec(self) -> ActionSpec:
        return ActionSpec(n_elements=self.cfg.n_wall_elements, low=0.0,
                          high=self.cfg.a_max)

    @property
    def n_actions(self) -> int:
        return self.cfg.n_actions

    def u_ref(self) -> jax.Array:
        """Reference mean profile (config-time constant, baked into step)."""
        return jnp.asarray(channel.reference_profile(self.cfg), jnp.float32)

    def initial_state_bank(self, key: jax.Array, n: int) -> jax.Array:
        return channel.make_state_bank(key, self.cfg, n)

    def reset_from_bank(self, bank: jax.Array, index: jax.Array
                        ) -> tuple[EnvState, jax.Array]:
        u = jnp.take(bank, index, axis=0)
        state = EnvState(u=u, t_step=jnp.zeros((), jnp.int32))
        return state, self.observe(state)

    def observe(self, state: EnvState) -> jax.Array:
        """Named-channel near-wall observation, both walls mirrored into the
        same orientation (cfd/channel.py wall_*_observation): velocities
        over u_bulk, plus the p_wall fluctuation over tau_wall when
        `obs_pressure` — (..., 2*Kx*Kz, n, n, n, C)."""
        obs = channel.wall_velocity_observation(state.u, self.cfg)
        obs = obs / self.cfg.u_bulk
        if self.obs_pressure:
            p = channel.wall_pressure_observation(state.u, self.cfg)
            obs = jnp.concatenate([obs, p / self.cfg.tau_wall], axis=-1)
        if self.obs_temperature:
            t = channel.wall_temperature_observation(state.u, self.cfg)
            obs = jnp.concatenate([obs, t / self.cfg.t_tau], axis=-1)
        return obs

    def _split_action(self, action: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
        """(..., 2*Kx*Kz) -> per-wall (..., Kx, Kz) scaling fields."""
        cfg = self.cfg
        kx, _, kz = cfg.n_elem
        a = jnp.clip(action, 0.0, cfg.a_max)
        grid = a.shape[:-1] + (kx, kz)
        bot = a[..., : kx * kz].reshape(grid)
        top = a[..., kx * kz:].reshape(grid)
        return bot, top

    def step(self, state: EnvState, action: jax.Array) -> StepResult:
        """One MDP transition with the shared in-graph blow-up guard: a
        non-finite advance reverts the state and floors the reward at -1
        (see cfd/env.py for the rationale)."""
        cfg = self.cfg
        scale_bot, scale_top = self._split_action(action)
        u_next = channel.advance_rl_interval(state.u, scale_bot, scale_top,
                                             cfg)
        finite = jnp.all(jnp.isfinite(u_next),
                         axis=tuple(range(u_next.ndim - 7, u_next.ndim)))
        u_next = jnp.where(
            finite[..., None, None, None, None, None, None, None],
            u_next, state.u)
        ops = cfg.operators()
        prof = channel.mean_velocity_profile(u_next, cfg, ops)
        ell = channel.profile_error(prof, self.u_ref(), ops)
        reward = jnp.where(finite,
                           spectra.reward_from_error(ell, cfg.alpha), -1.0)
        t_next = state.t_step + 1
        done = t_next >= cfg.n_actions
        next_state = EnvState(u=u_next, t_step=t_next)
        return StepResult(next_state, self.observe(next_state), reward, done)


@register("channel_wm")
def _channel_wm(**overrides) -> ChannelEnv:
    """Default scale: N=3, 3x4x3 elements, full-length episodes."""
    return ChannelEnv(cfg=ChannelConfig(**overrides))


@register("channel_wm_reduced")
def _channel_reduced(**overrides) -> ChannelEnv:
    """CPU-friendly smoke scale: 2x3x2 elements, short episodes."""
    defaults = dict(n_elem=(2, 3, 2), t_end=0.3, dt_rl=0.1)
    defaults.update(overrides)
    return ChannelEnv(cfg=ChannelConfig(**defaults))


@register("channel_wm_p")
def _channel_wm_p(**overrides) -> ChannelEnv:
    """4-channel variant: velocity + near-wall pressure observations."""
    return ChannelEnv(cfg=ChannelConfig(**overrides), obs_pressure=True)


@register("channel_wm_p_reduced")
def _channel_wm_p_reduced(**overrides) -> ChannelEnv:
    """CPU-friendly smoke scale of the 4-channel pressure variant."""
    defaults = dict(n_elem=(2, 3, 2), t_end=0.3, dt_rl=0.1)
    defaults.update(overrides)
    return ChannelEnv(cfg=ChannelConfig(**defaults), obs_pressure=True)


# Higher-Re_tau configuration: lower viscosity + higher target friction
# velocity push the matching point deep into the log layer (Re_tau =
# u_tau h / nu: 90 vs. the base 24), so the Reichardt inversion works at
# larger y+ — the fixed-point budget is scaled up with it (the "scaled
# Reichardt parameters" of the config family), and the initial
# perturbation amplitude rises to trip the stiffer profile.
_HRE = dict(nu=2e-3, u_tau=0.18, wm_iters=12, perturb=0.1)


@register("channel_wm_hre")
def _channel_wm_hre(**overrides) -> ChannelEnv:
    """Higher-Re_tau variant of `channel_wm` (Re_tau ~ 90)."""
    defaults = dict(_HRE)
    defaults.update(overrides)
    return ChannelEnv(cfg=ChannelConfig(**defaults))


@register("channel_wm_hre_reduced")
def _channel_wm_hre_reduced(**overrides) -> ChannelEnv:
    """CPU-friendly smoke scale of the higher-Re_tau variant."""
    defaults = dict(_HRE, n_elem=(2, 3, 2), t_end=0.3, dt_rl=0.1)
    defaults.update(overrides)
    return ChannelEnv(cfg=ChannelConfig(**defaults))


@register("channel_wm_t")
def _channel_wm_t(**overrides) -> ChannelEnv:
    """4-channel variant: velocity + near-wall temperature observations."""
    return ChannelEnv(cfg=ChannelConfig(**overrides), obs_temperature=True)


@register("channel_wm_t_reduced")
def _channel_wm_t_reduced(**overrides) -> ChannelEnv:
    """CPU-friendly smoke scale of the temperature variant."""
    defaults = dict(n_elem=(2, 3, 2), t_end=0.3, dt_rl=0.1)
    defaults.update(overrides)
    return ChannelEnv(cfg=ChannelConfig(**defaults), obs_temperature=True)

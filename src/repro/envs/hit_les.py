"""HIT-LES scenario (paper Sec. 5.2) on the generic Env protocol.

This is a thin, zero-cost adapter over the pure free functions in
`repro.cfd.env` — the numerics are byte-for-byte the pre-refactor HIT
environment (tests/test_envs.py pins the rollout arrays against a direct
composition of those free functions).  The adapter only declares the specs
and owns the synthetic-DNS reference spectrum that the reward compares
against (a numpy config-time constant, baked into the jitted step).

Observation channels (named, per `ObsSpec.channel_specs`): the three
velocity components ('u_x', 'u_y', 'u_z') at every element node, each
normalized by the forcing-scale rms velocity u_rms.

Registry overrides reach every `HITConfig` field, e.g.
`envs.make("hit_les_reduced", precision="bf16")` advances the flow state
in bfloat16 (obs/reward/PPO stay float32 — see HITConfig.precision), and
`use_kernels=True/False` forces the fused Pallas RHS path on or off.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..cfd import env as hit_kernel
from ..cfd import initial, spectra
from ..cfd.solver import HITConfig
from ..configs import relexi_hit
from .base import ActionSpec, EnvState, ObsSpec, StepResult, velocity_channels
from .registry import register


@dataclasses.dataclass(frozen=True)
class HITLESEnv:
    """Forced homogeneous isotropic turbulence LES, per-element C_s control."""

    cfg: HITConfig

    @property
    def obs_spec(self) -> ObsSpec:
        n = self.cfg.n_poly + 1
        return ObsSpec(n_elements=self.cfg.n_elem**3, spatial=(n, n, n),
                       channel_specs=velocity_channels(3, self.cfg.u_rms))

    @property
    def action_spec(self) -> ActionSpec:
        return ActionSpec(n_elements=self.cfg.n_elem**3, low=0.0,
                          high=self.cfg.cs_max)

    @property
    def n_actions(self) -> int:
        return self.cfg.n_actions

    def e_dns(self) -> jax.Array:
        """Synthetic DNS target spectrum (config-time constant)."""
        return jnp.asarray(spectra.reference_spectrum(self.cfg), jnp.float32)

    def initial_state_bank(self, key: jax.Array, n: int) -> jax.Array:
        return initial.make_state_bank(key, self.cfg, n)

    def reset_from_bank(self, bank: jax.Array, index: jax.Array
                        ) -> tuple[EnvState, jax.Array]:
        state, obs = hit_kernel.reset_from_bank(bank, index, self.cfg)
        return EnvState(*state), obs

    def observe(self, state: EnvState) -> jax.Array:
        return hit_kernel.observe(state.u, self.cfg)

    def step(self, state: EnvState, action: jax.Array) -> StepResult:
        res = hit_kernel.step(state, action, self.cfg, self.e_dns())
        return StepResult(EnvState(*res.state), res.obs, res.reward, res.done)


@register("hit_les_24dof")
def _hit24(**overrides) -> HITLESEnv:
    """Paper Table 1, 24-DOF configuration (N=5, 4^3 elements)."""
    return HITLESEnv(cfg=dataclasses.replace(relexi_hit.HIT24, **overrides))


@register("hit_les_32dof")
def _hit32(**overrides) -> HITLESEnv:
    """Paper Table 1, 32-DOF configuration (N=7, 4^3 elements)."""
    return HITLESEnv(cfg=dataclasses.replace(relexi_hit.HIT32, **overrides))


@register("hit_les_reduced")
def _hit_reduced(**overrides) -> HITLESEnv:
    """CPU-friendly smoke scale (N=3, 2^3 elements, short episodes)."""
    return HITLESEnv(cfg=dataclasses.replace(relexi_hit.reduced(), **overrides))

"""Forced 1-D Burgers control scenario on the generic Env protocol.

Proves the env abstraction end-to-end: a completely different solver
(1-D Burgers DGSEM, per-element eddy-viscosity control, 1-D specs) trains
through the *unchanged* runner/orchestrator/rollout/PPO stack that the
3-D HIT-LES scenario uses.  See cfd/burgers1d.py for the physics.

Observation channels (named, per `ObsSpec.channel_specs`): the single
scalar field 'u' at every element node, normalized by the forcing-scale
rms velocity u_rms.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..cfd import burgers1d, spectra
from ..cfd.burgers1d import BurgersConfig
from .base import ActionSpec, ChannelSpec, EnvState, ObsSpec, StepResult
from .registry import register


@dataclasses.dataclass(frozen=True)
class BurgersEnv:
    """Forced viscous Burgers LES, per-element eddy-viscosity control."""

    cfg: BurgersConfig

    @property
    def obs_spec(self) -> ObsSpec:
        return ObsSpec(n_elements=self.cfg.n_elem, spatial=(self.cfg.n,),
                       channel_specs=(ChannelSpec("u", scale=self.cfg.u_rms),))

    @property
    def action_spec(self) -> ActionSpec:
        return ActionSpec(n_elements=self.cfg.n_elem, low=0.0,
                          high=self.cfg.c_max)

    @property
    def n_actions(self) -> int:
        return self.cfg.n_actions

    def e_ref(self) -> jax.Array:
        """Synthetic k^-2 target spectrum (config-time constant)."""
        return jnp.asarray(burgers1d.reference_spectrum(self.cfg), jnp.float32)

    def initial_state_bank(self, key: jax.Array, n: int) -> jax.Array:
        return burgers1d.make_state_bank(key, self.cfg, n)

    def reset_from_bank(self, bank: jax.Array, index: jax.Array
                        ) -> tuple[EnvState, jax.Array]:
        u = jnp.take(bank, index, axis=0)
        state = EnvState(u=u, t_step=jnp.zeros((), jnp.int32))
        return state, self.observe(state)

    def observe(self, state: EnvState) -> jax.Array:
        return state.u / self.cfg.u_rms

    def step(self, state: EnvState, action: jax.Array) -> StepResult:
        """One MDP transition with the same in-graph blow-up guard as the
        HIT scenario: a non-finite advance reverts the state and floors the
        reward at -1 (see cfd/env.py for the rationale)."""
        cfg = self.cfg
        c_elem = jnp.clip(action, 0.0, cfg.c_max)
        u_next = burgers1d.advance_rl_interval(state.u, c_elem, cfg)
        finite = jnp.all(jnp.isfinite(u_next),
                         axis=tuple(range(u_next.ndim - 3, u_next.ndim)))
        u_next = jnp.where(finite[..., None, None, None], u_next, state.u)
        e_les = burgers1d.les_spectrum(u_next, cfg)
        ell = spectra.spectral_error(e_les, self.e_ref(), cfg.k_max)
        reward = jnp.where(finite, spectra.reward_from_error(ell, cfg.alpha),
                           -1.0)
        t_next = state.t_step + 1
        done = t_next >= cfg.n_actions
        next_state = EnvState(u=u_next, t_step=t_next)
        return StepResult(next_state, self.observe(next_state), reward, done)


@register("burgers_96dof")
def _burgers96(**overrides) -> BurgersEnv:
    """Production scale: N=7, 12 elements (96 DOF), full-length episodes."""
    return BurgersEnv(cfg=BurgersConfig(**overrides))


@register("burgers_reduced")
def _burgers_reduced(**overrides) -> BurgersEnv:
    """CPU-friendly smoke scale: N=3, 4 elements, short episodes."""
    defaults = dict(n_poly=3, n_elem=4, nu=2e-2, k_max=3, alpha=0.4,
                    t_end=0.3, dt_rl=0.1, k_eta=6.0)
    defaults.update(overrides)
    return BurgersEnv(cfg=BurgersConfig(**defaults))

"""String-keyed environment registry: `envs.make("hit_les_24dof")`.

The paper selects its scenario via a config name in the Relexi SLURM job;
here the registry is the same indirection for the jit-native envs.  A
factory may accept keyword overrides, which are forwarded verbatim — e.g.
`envs.make("hit_les_reduced", t_end=1.0)` rebuilds the underlying config
with that field replaced.

Every registered env declares its observation channels by NAME:

>>> from repro import envs
>>> envs.make("channel_wm_p_reduced").obs_spec.channel_names
('u_x', 'u_y', 'u_z', 'p_wall')
>>> envs.make("burgers_reduced").obs_spec.channel_names
('u',)
"""
from __future__ import annotations

from typing import Callable

from .base import Env

_REGISTRY: dict[str, Callable[..., Env]] = {}


def register(name: str) -> Callable[[Callable[..., Env]], Callable[..., Env]]:
    """Decorator registering an env factory under `name`."""

    def deco(factory: Callable[..., Env]) -> Callable[..., Env]:
        if name in _REGISTRY:
            raise ValueError(f"environment {name!r} already registered")
        _REGISTRY[name] = factory
        return factory

    return deco


def make(name: str, **overrides) -> Env:
    """Instantiate a registered environment, optionally overriding config
    fields (forwarded to the factory as keyword arguments)."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY)) or "<none>"
        raise KeyError(f"unknown environment {name!r}; registered: {known}"
                       ) from None
    return factory(**overrides)


def registered() -> tuple[str, ...]:
    """Sorted names of all registered environments."""
    return tuple(sorted(_REGISTRY))

"""Functional layers: dense, conv3d, norms, embeddings, initializers."""
from __future__ import annotations

from typing import Callable

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
import numpy as np

Initializer = Callable[[jax.Array, tuple[int, ...]], jax.Array]


# --- initializers ----------------------------------------------------------
def lecun_normal(fan_in_axes: tuple[int, ...] = (-2,)) -> Initializer:
    def init(key, shape):
        fan_in = int(np.prod([shape[a] for a in fan_in_axes]))
        return jax.random.normal(key, shape, jnp.float32) / np.sqrt(max(fan_in, 1))

    return init


def normal_init(stddev: float = 0.02) -> Initializer:
    def init(key, shape):
        return stddev * jax.random.normal(key, shape, jnp.float32)

    return init


def truncated_normal(stddev: float = 0.02) -> Initializer:
    def init(key, shape):
        return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)

    return init


def zeros_init() -> Initializer:
    def init(key, shape):
        return jnp.zeros(shape, jnp.float32)

    return init


# --- dense -----------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, *, bias: bool = True,
               w_init: Initializer | None = None) -> dict:
    w_init = w_init or lecun_normal((0,))
    kw, _ = jax.random.split(key)
    p = {"w": w_init(kw, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p: dict, x: jax.Array, *, dtype=None) -> jax.Array:
    w = p["w"].astype(dtype) if dtype is not None else p["w"]
    # named for remat policies: saving "gathered_weights" lets the backward
    # reuse the FSDP all-gather instead of re-issuing it (see §Perf)
    w = jax.ad_checkpoint.checkpoint_name(w, "gathered_weights")
    y = x @ w
    if "b" in p:
        b = p["b"].astype(y.dtype)
        y = y + b
    return y


# --- convNd (channels-last, any spatial rank 1..3) ---------------------------
_CONV_DIMNUMS = {
    1: ("NWC", "WIO", "NWC"),
    2: ("NHWC", "HWIO", "NHWC"),
    3: ("NDHWC", "DHWIO", "NDHWC"),
}


def convnd_init(key, k: int, c_in: int, c_out: int, *, ndim: int = 3,
                bias: bool = True) -> dict:
    fan_in = k**ndim * c_in
    kw, _ = jax.random.split(key)
    # He-normal (ReLU net in the policy)
    w = jax.random.normal(kw, (k,) * ndim + (c_in, c_out), jnp.float32)
    w = w * np.sqrt(2.0 / fan_in)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((c_out,), jnp.float32)
    return p


def convnd(p: dict, x: jax.Array, *, ndim: int = 3,
           padding: str = "VALID") -> jax.Array:
    """x: (..., *spatial, C) with `ndim` spatial axes.  Flattens leading axes
    to one batch axis."""
    batch = x.shape[: -(ndim + 1)]
    x2 = x.reshape((-1,) + x.shape[-(ndim + 1):])
    y = jax.lax.conv_general_dilated(
        x2,
        p["w"].astype(x.dtype),
        window_strides=(1,) * ndim,
        padding=padding,
        dimension_numbers=_CONV_DIMNUMS[ndim],
    )
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y.reshape(batch + y.shape[1:])


def conv3d_init(key, k: int, c_in: int, c_out: int, *, bias: bool = True) -> dict:
    return convnd_init(key, k, c_in, c_out, ndim=3, bias=bias)


def conv3d(p: dict, x: jax.Array, *, padding: str = "VALID") -> jax.Array:
    """x: (..., D, H, W, C).  Flattens leading axes to one batch axis."""
    return convnd(p, x, ndim=3, padding=padding)


# --- norms -------------------------------------------------------------------
def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, *, eps: float = 1e-6,
            scale_plus_one: bool = False) -> jax.Array:
    """RMSNorm in f32, cast back to input dtype (gemma uses (1+scale))."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = p["scale"] + 1.0 if scale_plus_one else p["scale"]
    return (x * scale).astype(dt)


def layernorm_init(d: int, *, bias: bool = True) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if bias:
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def layernorm(p: dict, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    x = x * p["scale"]
    if "bias" in p:
        x = x + p["bias"]
    return x.astype(dt)


# --- embedding ---------------------------------------------------------------
def embedding_init(key, vocab: int, d: int, *, stddev: float | None = None) -> dict:
    stddev = 1.0 / np.sqrt(d) if stddev is None else stddev
    return {"table": stddev * jax.random.normal(key, (vocab, d), jnp.float32)}


# --- utilities ---------------------------------------------------------------
def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))

"""Minimal functional neural-network layer library.

No flax/haiku offline — this is a deliberately small, explicit pytree-of-dicts
parameter system.  Every layer is a pair of pure functions:

    params = layer.init(key, ...)        # pytree of jnp arrays
    out    = layer.apply(params, x, ...)

Parameters are stored in float32 ("master" precision); compute-dtype casting
is the caller's concern (see models/transformer.py).
"""
from .layers import (
    Initializer,
    conv3d,
    conv3d_init,
    convnd,
    convnd_init,
    dense,
    dense_init,
    embedding_init,
    layernorm,
    layernorm_init,
    lecun_normal,
    normal_init,
    param_count,
    rmsnorm,
    rmsnorm_init,
    truncated_normal,
    zeros_init,
)

__all__ = [
    "Initializer",
    "dense",
    "dense_init",
    "conv3d",
    "conv3d_init",
    "convnd",
    "convnd_init",
    "embedding_init",
    "layernorm",
    "layernorm_init",
    "rmsnorm",
    "rmsnorm_init",
    "lecun_normal",
    "normal_init",
    "truncated_normal",
    "zeros_init",
    "param_count",
]

"""Logical-axis sharding: rules map logical array axes -> mesh axes.

Model code never names mesh axes; it annotates values with *logical* axes
("batch", "seq", "heads", "mlp", "experts", ...) via `constrain`.  A rules
context binds logical -> physical for the current mesh, with automatic
divisibility fallback: a logical axis whose dimension does not divide its
mesh-axis product is silently left unsharded (e.g. hymba's 25 heads on a
16-way model axis) — the 2D layouts keep working across all ten assigned
architectures without per-arch special cases.

Default rule set (the baseline the §Perf iterations start from):

    batch    -> ("pod", "data")     activations / env fleet
    embed    -> "data"              FSDP on the weight's d_model axis
    heads    -> "model"             attention-head parallel
    kv_heads -> "model"
    mlp      -> "model"             FFN hidden tensor-parallel
    experts  -> "model"             expert parallel
    vocab    -> "model"             embedding/logit shard
    seq      -> None                (sequence parallel is a §Perf change)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "seq": None,
    "kv_seq": "model",   # decode KV caches: sequence-shard over `model`
    "act_seq": "model",  # stored residual stream (Megatron-style SP)
    "state": None,
}


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """Version-agnostic `jax.sharding.AbstractMesh` constructor.

    jax changed the signature from a single `((name, size), ...)` tuple to
    separate `(axis_sizes, axis_names)` arguments; divisibility logic here
    only ever needs `mesh.shape`, so accept the modern spelling and build
    whichever the installed jax expects.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # older jax: AbstractMesh(shape_tuple)
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))


class AxisRules:
    def __init__(self, mesh: Mesh | None, rules: dict[str, Any] | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def mesh_axes(self, logical: str | None):
        if logical is None:
            return None
        return self.rules.get(logical)


_state = threading.local()


def current_rules() -> AxisRules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh | None, rules: dict[str, Any] | None = None):
    """Bind logical->mesh rules for model code executed in this context.

    NOTE: the context must be live at TRACE time (jit tracing), which is the
    natural usage: `with mesh, axis_rules(mesh): jitted(...)`.
    """
    prev = current_rules()
    _state.rules = AxisRules(mesh, rules)
    try:
        yield _state.rules
    finally:
        _state.rules = prev


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    return int(np.prod([mesh.shape[a] for a in axes]))


def logical_to_spec(shape: tuple[int, ...], logical: tuple[str | None, ...],
                    rules: AxisRules) -> P:
    """PartitionSpec for `shape` under `rules`, dropping non-divisible axes."""
    assert len(shape) == len(logical), (shape, logical)
    if rules.mesh is None:
        return P()
    spec = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        axes = rules.mesh_axes(name)
        if axes is None:
            spec.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        # drop axes already consumed by an earlier dim of this array
        axes_t = tuple(a for a in axes_t if a not in used and a in rules.mesh.shape)
        if not axes_t or dim % _axis_size(rules.mesh, axes_t) != 0:
            spec.append(None)
            continue
        used.update(axes_t)
        spec.append(axes_t[0] if len(axes_t) == 1 else axes_t)
    return P(*spec)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a rules ctx."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = logical_to_spec(x.shape, logical, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def named_sharding(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, P(*axes))


def param_specs(params: Any, logical_axes: Any, rules: AxisRules) -> Any:
    """Pytree of PartitionSpec for a parameter pytree.

    `logical_axes` mirrors `params` with tuples of logical names per leaf
    (see models/*.py `param_axes`).  Leaves without an entry are replicated.
    """
    def is_axes_leaf(x):
        return x is None or (
            isinstance(x, tuple)
            and all(isinstance(s, str) or s is None for s in x)
        )

    flat_p, tdef = jax.tree.flatten(params)
    flat_ax = jax.tree.flatten(logical_axes, is_leaf=is_axes_leaf)[0]
    if len(flat_p) != len(flat_ax):
        raise ValueError(
            f"params has {len(flat_p)} leaves but logical_axes {len(flat_ax)}")
    specs = [P() if ax is None else logical_to_spec(p.shape, ax, rules)
             for p, ax in zip(flat_p, flat_ax)]
    return jax.tree.unflatten(tdef, specs)

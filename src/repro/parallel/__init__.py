"""Distribution primitives: logical-axis sharding rules and mesh helpers."""
from .sharding import (
    AxisRules,
    axis_rules,
    constrain,
    current_rules,
    logical_to_spec,
    named_sharding,
    param_specs,
)

__all__ = [
    "AxisRules",
    "axis_rules",
    "constrain",
    "current_rules",
    "logical_to_spec",
    "named_sharding",
    "param_specs",
]

"""Public kernel API: impl dispatch + differentiation glue.

Every op takes `impl`:
  None         resolved from `policy.default_impl()`: "kernel" on TPU,
               "ref" elsewhere (the solver configs' `use_kernels=None` auto).
  "kernel"     Pallas kernel, interpret mode auto-selected off-TPU (tests,
               CPU container), compiled on TPU.  Gradients: custom_vjp with
               recompute-from-ref backward (fwd speed where it matters; bwd
               correctness from the oracle — the backward kernels are listed
               as future work in DESIGN.md §Kernels).
  "ref"        the pure-jnp oracle from ref.py (solver ops).
  "chunked"    pure-jnp flash/chunk-equivalent (differentiable end-to-end,
               compilable on every backend) — the dry-run / training path.
  "naive"      full-materialization reference — tests and tiny shapes only.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import ref
from .dg_derivative import dg_derivative3 as _dg_pallas
from .flash_attention import flash_attention as _fa_pallas
from .linear_scan import linear_scan as _ls_pallas
from .policy import default_impl
from .rhs import fused_navier_stokes_rhs as _rhs_pallas
from .smagorinsky import smagorinsky_nut as _smag_pallas
from .wall_model import wall_model_tau as _wm_pallas


# --- dg derivative -----------------------------------------------------------
def dg_derivative3(u: jax.Array, d_matrix: jax.Array, *,
                   impl: str | None = None,
                   block_b: int = 256) -> tuple[jax.Array, ...]:
    if (impl or default_impl()) == "kernel":
        return _dg_pallas(u, d_matrix, block_b=block_b)
    return ref.dg_derivative3(u, d_matrix)


# --- smagorinsky -------------------------------------------------------------
def smagorinsky_nut(grad_v: jax.Array, cs: jax.Array, delta: float, *,
                    impl: str | None = None, block_p: int = 2048) -> jax.Array:
    if (impl or default_impl()) == "kernel":
        return _smag_pallas(grad_v, cs, delta, block_p=block_p)
    return ref.smagorinsky_nut(grad_v, cs, delta)


# --- fused Navier-Stokes RHS -------------------------------------------------
def navier_stokes_rhs_fused(u: jax.Array, cs_nodes: jax.Array,
                            d_matrix: jax.Array, w: jax.Array, *,
                            inv_w_end: tuple[float, float], jac: float,
                            delta: float, mu: float, prandtl: float,
                            prandtl_turb: float, forcing_a0: float,
                            k_tke: float, impl: str | None = None,
                            block_e: int = 1) -> jax.Array:
    """One fused periodic-HIT RHS evaluation (see kernels/rhs.py) — the op
    `cfd/solver.navier_stokes_rhs` dispatches to when kernels are enabled."""
    kw = dict(inv_w_end=inv_w_end, jac=jac, delta=delta, mu=mu,
              prandtl=prandtl, prandtl_turb=prandtl_turb,
              forcing_a0=forcing_a0, k_tke=k_tke)
    if (impl or default_impl()) == "kernel":
        return _rhs_pallas(u, cs_nodes, d_matrix, w, block_e=block_e, **kw)
    return ref.navier_stokes_rhs_fused(u, cs_nodes, d_matrix, w, **kw)


# --- wall model --------------------------------------------------------------
def wall_model_tau(u_par: jax.Array, rho_w: jax.Array, *, y_m: float,
                   nu: float, kappa: float = 0.41, iters: int = 8,
                   impl: str | None = None, block_p: int = 2048) -> jax.Array:
    """Reichardt-inverted wall stress for a batch of wall-face points."""
    if (impl or default_impl()) == "kernel":
        return _wm_pallas(u_par, rho_w, y_m=y_m, nu=nu, kappa=kappa,
                          iters=iters, block_p=block_p)
    return ref.wall_model_tau(u_par, rho_w, y_m=y_m, nu=nu, kappa=kappa,
                              iters=iters)


# --- flash attention ---------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fa_with_vjp(q, k, v, causal, window, softcap, scale):
    return _fa_pallas(q, k, v, causal=causal, window=window, softcap=softcap,
                      scale=scale)


def _fa_fwd(q, k, v, causal, window, softcap, scale):
    return _fa_with_vjp(q, k, v, causal, window, softcap, scale), (q, k, v)


def _fa_bwd(causal, window, softcap, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.mha_chunked(q, k, v, causal=causal, window=window,
                                        softcap=softcap, scale=scale), q, k, v)
    return vjp(g)


_fa_with_vjp.defvjp(_fa_fwd, _fa_bwd)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    impl: str = "chunked",
    block_k: int = 1024,
    unroll: bool = False,
) -> jax.Array:
    """GQA attention, q (B,Hq,Sq,D), kv (B,Hkv,Skv,D) -> (B,Hq,Sq,D)."""
    if impl == "kernel":
        return _fa_with_vjp(q, k, v, causal, window, softcap, scale)
    if impl == "chunked":
        return ref.mha_chunked(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               block_k=min(block_k, k.shape[2]),
                               unroll=unroll)
    if impl == "naive":
        return ref.mha(q, k, v, causal=causal, window=window, softcap=softcap,
                       scale=scale)
    raise ValueError(f"unknown attention impl: {impl}")


# --- gated linear recurrence ---------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _ls_with_vjp(q, k, v, w, u, s0, decay_before_read):
    return _ls_pallas(q, k, v, w, u, s0, decay_before_read=decay_before_read)


def _ls_fwd(q, k, v, w, u, s0, decay_before_read):
    return _ls_with_vjp(q, k, v, w, u, s0, decay_before_read), (q, k, v, w, u, s0)


def _ls_bwd(decay_before_read, res, g):
    q, k, v, w, u, s0 = res
    _, vjp = jax.vjp(
        lambda *a: ref.linear_scan_chunked(*a, decay_before_read=decay_before_read),
        q, k, v, w, u, s0)
    return vjp(g)


_ls_with_vjp.defvjp(_ls_fwd, _ls_bwd)


def gated_linear_scan(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array | None = None,
    s0: jax.Array | None = None,
    *,
    decay_before_read: bool = False,
    impl: str = "chunked",
    chunk: int = 64,
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(o, s_final) of the gated linear recurrence (see ref.linear_scan)."""
    if impl == "kernel":
        if u is None or s0 is None:  # custom_vjp wants concrete args
            b, _, dk = q.shape
            u = jnp.zeros((dk,), q.dtype) if u is None else u
            s0 = jnp.zeros((b, dk, v.shape[-1]), jnp.float32) if s0 is None else s0
        return _ls_with_vjp(q, k, v, w, u, s0, decay_before_read)
    if impl == "chunked":
        if unroll:  # cap the unrolled body count (dry-run calibration);
            # inflates only the tiny intra-chunk term (DESIGN.md §5b)
            chunk = max(chunk, q.shape[1] // 16)
        return ref.linear_scan_chunked(q, k, v, w, u, s0,
                                       decay_before_read=decay_before_read,
                                       chunk=chunk, unroll=unroll)
    if impl == "scan":
        return ref.linear_scan(q, k, v, w, u, s0,
                               decay_before_read=decay_before_read)
    raise ValueError(f"unknown linear-scan impl: {impl}")

"""Pallas TPU kernel: fused Smagorinsky eddy-viscosity chain (paper Eq. 3).

    S_ij  = (grad_v + grad_v^T) / 2
    |S|   = sqrt(2 S_ij S_ij)
    nu_t  = (C_s * Delta)^2 |S|

The chain is purely elementwise over solution points and is memory-bound;
unfused, XLA materializes S_ij (9 floats/point) and |S| between HBM round
trips on the viscous path.  The fused kernel reads the 9 gradient components
and one C_s per point and writes a single nu_t: 40 B/point (10 in + 1 out
won't fit better) versus ~88 B/point unfused — a 2.2x traffic cut on this
link of the RHS (EXPERIMENTS.md §Perf).

Layout: point-flattened (P, 9) gradients (row-major i, j of dv_i/dx_j),
(P,) coefficients; grid over P blocks; Delta is a compile-time constant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .policy import resolve_interpret


def _kernel(grad_ref, cs_ref, nut_ref, *, delta: float):
    g = grad_ref[...].astype(jnp.float32)  # (Pb, 9): dv_i/dx_j row-major
    cs = cs_ref[...].astype(jnp.float32)   # (Pb,)
    # 2 * S_ij S_ij = 2 * sum_ij ((g_ij + g_ji)/2)^2
    #              = sum_ij g_ij^2 + g_ij g_ji   (expanded, no transpose mat)
    g2 = jnp.sum(g * g, axis=-1)
    # cross terms g_ij * g_ji: pairs (0,1)-(1,0)=(1,3), (0,2)-(2,0)=(2,6),
    # (1,2)-(2,1)=(5,7); diagonals pair with themselves.
    cross = (
        g[:, 0] * g[:, 0] + g[:, 4] * g[:, 4] + g[:, 8] * g[:, 8]
        + 2.0 * (g[:, 1] * g[:, 3] + g[:, 2] * g[:, 6] + g[:, 5] * g[:, 7])
    )
    s_mag = jnp.sqrt(g2 + cross + 1e-30)
    nut = (cs * delta) ** 2 * s_mag
    nut_ref[...] = nut.astype(nut_ref.dtype)


@functools.partial(jax.jit, static_argnames=("delta", "block_p", "interpret"))
def smagorinsky_nut(
    grad_v: jax.Array,
    cs: jax.Array,
    delta: float,
    *,
    block_p: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """nu_t for point-flattened inputs; matches kernels.ref.smagorinsky_nut.

    grad_v: (P, 3, 3);  cs: (P,).  Returns (P,).
    """
    p = grad_v.shape[0]
    g = grad_v.reshape(p, 9)
    block_p = min(block_p, p)
    pad = (-p) % block_p
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
        cs = jnp.pad(cs, (0, pad))
    pp = p + pad
    nut = pl.pallas_call(
        functools.partial(_kernel, delta=delta),
        grid=(pp // block_p,),
        in_specs=[
            pl.BlockSpec((block_p, 9), lambda i: (i, 0)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), grad_v.dtype),
        interpret=resolve_interpret(interpret),
        name="smagorinsky_nut",
    )(g, cs)
    return nut[:p] if pad else nut

"""Pallas TPU kernels for the perf-critical compute layers.

  dg_derivative    fused 3-direction DGSEM derivative (solver volume terms)
  smagorinsky      fused strain-rate -> eddy-viscosity chain (paper Eq. 3)
  flash_attention  blockwise-softmax attention (GQA/causal/SWA/softcap)
  linear_scan      chunk-parallel gated linear recurrence (RWKV6/SSM)

Use through `ops` (impl dispatch + autodiff glue); `ref` holds the pure-jnp
oracles every kernel is validated against (tests/test_kernels.py).
"""
from . import ops, ref

__all__ = ["ops", "ref"]

"""Pallas TPU kernels for the perf-critical compute layers.

  rhs              fused DGSEM Navier-Stokes RHS mega-kernel: one launch per
                   element batch covering derivative -> flux -> Smagorinsky
                   -> divergence + forcing, intermediates in VMEM (the
                   periodic HIT production path)
  dg_derivative    fused 3-direction DGSEM derivative (solver volume terms)
  smagorinsky      fused strain-rate -> eddy-viscosity chain (paper Eq. 3)
  wall_model       batched Reichardt law-of-the-wall fixed-point inversion
                   (the channel WMLES per-step hot loop)
  flash_attention  blockwise-softmax attention (GQA/causal/SWA/softcap)
  linear_scan      chunk-parallel gated linear recurrence (RWKV6/SSM)

Use through `ops` (impl dispatch + autodiff glue); `ref` holds the pure-jnp
oracles every kernel is validated against — the three solver kernels in the
`kernel_parity` CI gate (tests/test_kernel_parity.py), flash_attention and
linear_scan in tests/test_kernels.py.  `default_impl()`/`default_interpret()`
are the single backend policy: kernels are ON and compiled when
`jax.default_backend() == "tpu"`, and interpret-mode oracles elsewhere —
configs opt out (or force on) via their `use_kernels` field, and the
`REPRO_KERNELS={kernel,ref,auto}` env var retargets the auto default
without code edits (see policy.py).
"""
from . import ops, ref
from .policy import default_impl, default_interpret

__all__ = ["ops", "ref", "default_impl", "default_interpret"]

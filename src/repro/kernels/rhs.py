"""Pallas TPU mega-kernel: one fused DGSEM Navier-Stokes RHS evaluation.

The periodic HIT RHS is ~a dozen separate XLA ops (primitive decode, BR1
gradient, eddy viscosity, three flux/divergence passes, forcing) with the
full nodal state written to and re-read from HBM between stages — each
intermediate is mesh-sized, so an RK5 substep moves ~30 state-sized buffers
through HBM per RHS call.  This kernel computes the whole evaluation —
DG derivative -> viscous/convective flux -> Smagorinsky eddy viscosity ->
divergence + forcing — in a single launch with every intermediate resident
in VMEM: per grid step it reads one element-batch block of (u, cs_nodes)
and writes one block of rhs (2 state-sized HBM transfers total).

Grid layout: the environment batch is flattened and gridded in blocks of
`block_e` WHOLE meshes, (block_e, Kx, Ky, Kz, n, n, n, 5) per block.  A
block holds complete meshes because the RHS is not element-local: the
surface exchange couples neighbor elements (periodic rolls along the
element axes) and the Lundgren forcing needs whole-box quadrature means —
both stay in-kernel when the mesh is resident.  At paper scale a mesh is
small (24-DOF HIT: 4^3 elements x 6^3 nodes x 5 channels = 540 KB in f32),
so mesh + intermediates fit VMEM comfortably; `block_e` trades VMEM
footprint against grid-step count for large env batches.

The kernel body calls `ref.navier_stokes_rhs_fused` on its block values —
kernel and oracle share one op order by construction, which is what the
`kernel_parity` gate (tests/test_kernel_parity.py) pins.  Internal math is
float32 regardless of I/O dtype; bf16 in/out serves the mixed-precision
rollout (HITConfig.precision = "bf16").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .policy import resolve_interpret


def _kernel(u_ref, cs_ref, d_ref, w_ref, rhs_ref, *, inv_w_end, jac, delta,
            mu, prandtl, prandtl_turb, forcing_a0, k_tke):
    rhs_ref[...] = ref.navier_stokes_rhs_fused(
        u_ref[...], cs_ref[...], d_ref[...], w_ref[...],
        inv_w_end=inv_w_end, jac=jac, delta=delta, mu=mu, prandtl=prandtl,
        prandtl_turb=prandtl_turb, forcing_a0=forcing_a0, k_tke=k_tke)


@functools.partial(jax.jit, static_argnames=(
    "inv_w_end", "jac", "delta", "mu", "prandtl", "prandtl_turb",
    "forcing_a0", "k_tke", "block_e", "interpret"))
def fused_navier_stokes_rhs(
    u: jax.Array,
    cs_nodes: jax.Array,
    d_matrix: jax.Array,
    w: jax.Array,
    *,
    inv_w_end: tuple[float, float],
    jac: float,
    delta: float,
    mu: float,
    prandtl: float,
    prandtl_turb: float,
    forcing_a0: float,
    k_tke: float,
    block_e: int = 1,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused RHS for an arbitrary batch of HIT meshes.

    u: (..., Kx, Ky, Kz, n, n, n, 5); cs_nodes shaped like u[..., 0];
    d_matrix (n, n); w (n,) GLL weights; scalars as in the oracle.  Returns
    the RHS with u's shape and dtype.  Matches ref.navier_stokes_rhs_fused.
    """
    mesh = u.shape[-7:]
    n = mesh[3]
    ub = u.reshape((-1,) + mesh)
    csb = cs_nodes.reshape((-1,) + mesh[:-1])
    b = ub.shape[0]
    block_e = max(1, min(block_e, b))
    pad = (-b) % block_e
    if pad:
        # pad with copies of the first mesh: every padded lane is a valid
        # flow state, so no inf/nan can leak out of the discarded blocks
        ub = jnp.concatenate(
            [ub, jnp.broadcast_to(ub[:1], (pad,) + mesh)], axis=0)
        csb = jnp.concatenate(
            [csb, jnp.broadcast_to(csb[:1], (pad,) + mesh[:-1])], axis=0)
    bp = b + pad
    out = pl.pallas_call(
        functools.partial(_kernel, inv_w_end=inv_w_end, jac=jac, delta=delta,
                          mu=mu, prandtl=prandtl, prandtl_turb=prandtl_turb,
                          forcing_a0=forcing_a0, k_tke=k_tke),
        grid=(bp // block_e,),
        in_specs=[
            pl.BlockSpec((block_e,) + mesh, lambda i: (i,) + (0,) * 7),
            pl.BlockSpec((block_e,) + mesh[:-1], lambda i: (i,) + (0,) * 6),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_e,) + mesh, lambda i: (i,) + (0,) * 7),
        out_shape=jax.ShapeDtypeStruct((bp,) + mesh, u.dtype),
        interpret=resolve_interpret(interpret),
        name="fused_ns_rhs",
    )(ub, csb, d_matrix, w)
    return out[:b].reshape(u.shape)

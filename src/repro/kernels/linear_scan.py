"""Pallas TPU kernel: chunk-parallel gated linear recurrence.

Serves the attention-free / hybrid cells (rwkv6-1.6b, hymba-1.5b's mamba
heads) and is what makes the 500k-token long-context cells tractable: the
recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state (dk, dv))
    o_t = q_t @ (S_{t-1} + diag(u) k_t v_t^T)    (RWKV6 read)
    o_t = q_t @ S_t                              (GLA/Mamba read)

is restructured into chunks of length C: the O(T) sequential dependence is
carried as one (dk, dv) VMEM-resident state between chunks, while within a
chunk everything is dense MXU work:

    intra: A[t,s] = sum_d q_t[d] k_s[d] exp(cw_t[d] - cw_s[d]),  s <(=) t
    inter: o += (q * exp(cw)) @ S_chunk_start
    state: S' = diag(exp(cw_last)) S + (k * exp(cw_last - cw))^T V

Stability: w in (0, 1], so every exponent above is <= 0 for the masked
(s <= t) entries — the chunk boundary IS the factorization point, no
log-space ratio ever exceeds 1 (this is why the kernel never needs the
fp64 workarounds a naive Q/W, K*W factorization would).

Grid: (B*H, T/C) with the chunk axis sequential; the state is VMEM scratch
and is also emitted as a second output (decode caches it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ._compat import tpu_compiler_params
from .policy import resolve_interpret


def _kernel(
    q_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
    o_ref, sfin_ref,
    s_scr,
    *, chunk: int, n_chunks: int, decay_before_read: bool, has_u: bool,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)  # (C, dk)
    k = k_ref[0].astype(jnp.float32)  # (C, dk)
    v = v_ref[0].astype(jnp.float32)  # (C, dv)
    w = w_ref[0].astype(jnp.float32)  # (C, dk)
    s = s_scr[...]                     # (dk, dv)

    log_w = jnp.log(jnp.maximum(w, 1e-30))
    cw = jnp.cumsum(log_w, axis=0)    # (C, dk): log prod_{s<=t} w_s

    if decay_before_read:
        # GLA: read after decay+write -> decay factor for q_t is exp(cw_t),
        # intra-pair exponent cw_t - cw_s for s <= t (diag: 0).
        q_decay = jnp.exp(cw)
        pair = cw[:, None, :] - cw[None, :, :]          # (C, C, dk)
        mask = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    else:
        # RWKV6: read BEFORE decay/write -> q_t sees exp(cw_{t-1}); strict
        # lower-triangular pairs, diagonal handled by the u-bonus below.
        cw_prev = jnp.concatenate([jnp.zeros_like(cw[:1]), cw[:-1]], axis=0)
        q_decay = jnp.exp(cw_prev)
        pair = cw_prev[:, None, :] - cw[None, :, :]     # (C, C, dk)
        mask = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), k=-1)

    pair = jnp.where(mask[:, :, None], pair, -jnp.inf)  # exponent <= 0 kept
    a = jnp.einsum("td,sd,tsd->ts", q, k, jnp.exp(pair))
    if not decay_before_read:
        diag = jnp.sum(q * (u_ref[...].astype(jnp.float32) * k if has_u else k),
                       axis=-1)
        a = a + jnp.diag(diag)

    o_intra = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_inter = jax.lax.dot_general(
        q * q_decay, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = (o_intra + o_inter).astype(o_ref.dtype)

    # state update: S' = diag(exp(cw_last)) S + (k * exp(cw_last - cw))^T V
    k_decay = jnp.exp(cw[-1][None, :] - cw)             # (C, dk), <= 1
    s_new = jnp.exp(cw[-1])[:, None] * s + jax.lax.dot_general(
        k * k_decay, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        sfin_ref[0] = s_new.astype(sfin_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("decay_before_read", "chunk", "interpret"),
)
def linear_scan(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array | None = None,
    s0: jax.Array | None = None,
    *,
    decay_before_read: bool = False,
    chunk: int = 64,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked gated linear recurrence; contract = kernels.ref.linear_scan.

    q, k, w: (B, T, dk);  v: (B, T, dv);  u: (dk,) or None;
    s0: (B, dk, dv) or None.  Returns (o: (B, T, dv), s_final: (B, dk, dv)).
    """
    b, t, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        # w=1 on padding -> no decay; k=0 -> no state writes; q=0 -> o=0 rows
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    tp = t + pad
    n_chunks = tp // chunk
    has_u = u is not None
    u_in = u if has_u else jnp.zeros((dk,), q.dtype)
    s0_in = s0 if s0 is not None else jnp.zeros((b, dk, dv), jnp.float32)

    kern = functools.partial(
        _kernel, chunk=chunk, n_chunks=n_chunks,
        decay_before_read=decay_before_read, has_u=has_u,
    )
    compiler_params = tpu_compiler_params(("parallel", "arbitrary"))
    o, s_fin = pl.pallas_call(
        kern,
        grid=(b, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, dk), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, dv), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, dk), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((dk,), lambda ib, ic: (0,)),
            pl.BlockSpec((1, dk, dv), lambda ib, ic: (ib, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, dk, dv), lambda ib, ic: (ib, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, tp, dv), q.dtype),
            jax.ShapeDtypeStruct((b, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=resolve_interpret(interpret),
        name="linear_scan",
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(q, k, v, w, u_in, s0_in)
    return (o[:, :t] if pad else o), s_fin

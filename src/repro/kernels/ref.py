"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantic contracts*: tests sweep shapes/dtypes and assert
allclose(kernel(interpret=True), ref).  They are also the implementations
used on non-TPU backends and inside the multi-pod dry-run (Pallas lowers for
TPU; the CPU dry-run must still produce a compilable, cost-analyzable HLO,
and the chunked/flash reference forms below have the same asymptotic
FLOP/byte behavior as the kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --- dg_derivative -----------------------------------------------------------
def dg_derivative3(u: jax.Array, d_matrix: jax.Array) -> tuple[jax.Array, ...]:
    """Fused 3-direction DGSEM derivative.

    u: (B, n, n, n, C) element batch; d_matrix: (n, n).
    Returns (du0, du1, du2) with du_d = derivative along intra-element axis d.
    """
    du0 = jnp.einsum("im,bmjkc->bijkc", d_matrix, u)
    du1 = jnp.einsum("jm,bimkc->bijkc", d_matrix, u)
    du2 = jnp.einsum("km,bijmc->bijkc", d_matrix, u)
    return du0, du1, du2


# --- smagorinsky -------------------------------------------------------------
def smagorinsky_nut(grad_v: jax.Array, cs: jax.Array, delta: float) -> jax.Array:
    """Fused strain-rate -> eddy-viscosity chain (paper Eq. 3).

    grad_v: (P, 3, 3) with grad_v[p, i, j] = d v_i / d x_j at point p.
    cs:     (P,) per-point Smagorinsky coefficient (element value broadcast).
    Returns nu_t: (P,) = (cs * delta)^2 * sqrt(2 S_ij S_ij).
    """
    s = 0.5 * (grad_v + jnp.swapaxes(grad_v, -1, -2))
    s_mag = jnp.sqrt(2.0 * jnp.sum(s * s, axis=(-1, -2)) + 1e-30)
    return (cs * delta) ** 2 * s_mag


# --- wall model --------------------------------------------------------------
def reichardt_uplus(y_plus, kappa: float = 0.41, xp=jnp):
    """Reichardt's composite law of the wall u+(y+): blends the viscous
    sublayer (u+ = y+), buffer layer and log law smoothly — valid at every
    y+, which is what lets one formula serve both the wall model and the
    reference profile at smoke-scale Reynolds numbers.  `xp` lets the same
    formula run under numpy for config-time reference profiles
    (cfd.channel re-exports this)."""
    return (xp.log1p(kappa * y_plus) / kappa
            + 7.8 * (1.0 - xp.exp(-y_plus / 11.0)
                     - (y_plus / 11.0) * xp.exp(-y_plus / 3.0)))


def wall_model_tau(u_par: jax.Array, rho_w: jax.Array, *, y_m: float,
                   nu: float, kappa: float = 0.41,
                   iters: int = 8) -> jax.Array:
    """tau_w = rho u_tau^2 by inverting u_par/u_tau = u+(y_m u_tau / nu).

    Geometrically-damped fixed point: in the viscous limit (u+ ~ y+) the
    damped map lands on the exact laminar stress mu u_par / y_m in one step,
    and in the log regime it contracts; `iters` iterations unroll into the
    jitted RHS.  Oracle for kernels/wall_model.py (identical op order).
    """
    f32 = jnp.float32
    up = u_par.astype(f32)
    u_tau = jnp.sqrt(nu * up / y_m + 1e-12)  # laminar initial guess
    for _ in range(iters):
        y_plus = y_m * u_tau / nu
        u_plus = jnp.maximum(reichardt_uplus(y_plus, kappa), 1e-6)
        u_tau = jnp.sqrt(u_tau * up / u_plus + 1e-14)
    return (rho_w.astype(f32) * u_tau**2).astype(u_par.dtype)


# --- fused Navier-Stokes RHS -------------------------------------------------
# Self-contained single-pass DGSEM RHS for the periodic HIT scenario — the
# oracle for kernels/rhs.py (the mega-kernel's body calls THIS function on
# its VMEM block, so kernel and oracle share one op order by construction).
# The constants and formulas mirror cfd/equations + cfd/dgsem; they are
# restated here because this module must stay a leaf (imports jax only — the
# kernels cannot cycle through the cfd package).  Two deliberate deviations
# from the cfd reference, both bit-identical in exact zeros:
#   * periodic rolls are slice+concatenate (jnp.roll is a gather that Mosaic
#     does not lower inside kernel bodies),
#   * the endpoint surface lift is a concatenation of the two corrected face
#     slabs around an exact-zero interior (no .at[].add scatter).

_GAMMA = 1.4
_R_GAS = 1.0
_CP = _GAMMA * _R_GAS / (_GAMMA - 1.0)
# element / intra-element node axes of the shared (..., Kx, Ky, Kz, n, n, n,
# C) state layout (cfd/dgsem.py module docstring)
_ELEM_AXIS = (-7, -6, -5)
_NODE_AXIS = (-4, -3, -2)


def _roll(x, shift: int, axis: int):
    """Circular shift by +-1 via slice+concatenate (see note above)."""
    n = x.shape[axis]
    if shift == -1:
        parts = (jax.lax.slice_in_dim(x, 1, n, axis=axis),
                 jax.lax.slice_in_dim(x, 0, 1, axis=axis))
    else:
        parts = (jax.lax.slice_in_dim(x, n - 1, n, axis=axis),
                 jax.lax.slice_in_dim(x, 0, n - 1, axis=axis))
    return jnp.concatenate(parts, axis=axis)


def _deriv_along(u, d_matrix, direction: int):
    axis = _NODE_AXIS[direction] + u.ndim
    moved = jnp.moveaxis(u, axis, -1)
    return jnp.moveaxis(moved @ d_matrix.T, -1, axis)


def _face_slices(u, direction: int):
    axis = _NODE_AXIS[direction] + u.ndim
    lo = jax.lax.index_in_dim(u, 0, axis, keepdims=False)
    hi = jax.lax.index_in_dim(u, u.shape[axis] - 1, axis, keepdims=False)
    return lo, hi


def _neighbor_traces(u, direction: int):
    lo, hi = _face_slices(u, direction)
    elem_axis = _ELEM_AXIS[direction] + lo.ndim + 1  # one axis was dropped
    return hi, _roll(lo, -1, elem_axis)


def _surface_lift(du, jump_right, jump_left, direction: int,
                  inv_w_end: tuple[float, float]):
    axis = _NODE_AXIS[direction] + du.ndim
    moved = jnp.moveaxis(du, axis, -1)
    inv_w0, inv_wn = inv_w_end
    corr = jnp.concatenate([
        (-inv_w0 * jump_left)[..., None],
        jnp.zeros(moved.shape[:-1] + (moved.shape[-1] - 2,), moved.dtype),
        (inv_wn * jump_right)[..., None],
    ], axis=-1)
    return jnp.moveaxis(moved + corr, -1, axis)


def _primitives(u):
    rho = u[..., 0]
    vel = u[..., 1:4] / rho[..., None]
    kinetic = 0.5 * rho * jnp.sum(vel * vel, axis=-1)
    p = (_GAMMA - 1.0) * (u[..., 4] - kinetic)
    temp = p / (rho * _R_GAS)
    return rho, vel, p, temp


def _mom_flux(base, per_comp, p, direction: int):
    """Momentum flux columns base_i (+ p on the flux-direction component),
    assembled per component — the pressure add targets one channel without a
    scatter or a captured one-hot constant (Pallas-body constraints)."""
    cols = []
    for i in range(3):
        c = base * per_comp[..., i]
        if i == direction:
            c = c + p
        cols.append(c[..., None])
    return jnp.concatenate(cols, axis=-1)


def _advective_flux(u, direction: int):
    rho, vel, p, _ = _primitives(u)
    vn = vel[..., direction]
    f_rho = u[..., 1 + direction]
    f_mom = _mom_flux(vn, u[..., 1:4], p, direction)
    f_e = (u[..., 4] + p) * vn
    return jnp.concatenate([f_rho[..., None], f_mom, f_e[..., None]], axis=-1)


def _lax_friedrichs(u_l, u_r, direction: int):
    rho_l, vel_l, p_l, _ = _primitives(u_l)
    rho_r, vel_r, p_r, _ = _primitives(u_r)
    c_l = jnp.sqrt(_GAMMA * p_l / rho_l)
    c_r = jnp.sqrt(_GAMMA * p_r / rho_r)
    lam = jnp.maximum(jnp.abs(vel_l[..., direction]) + c_l,
                      jnp.abs(vel_r[..., direction]) + c_r)
    f_l = _advective_flux(u_l, direction)
    f_r = _advective_flux(u_r, direction)
    return 0.5 * (f_l + f_r) - 0.5 * lam[..., None] * (u_r - u_l)


def _flux_differencing(prim, d_matrix, direction: int):
    """Split-form volume integral with the Kennedy-Gruber two-point flux
    (all-arithmetic-mean; cfd/equations.kennedy_gruber_flux inlined)."""
    def pairwise(q, is_vec):
        a = q.ndim + _NODE_AXIS[direction] + (0 if is_vec else 1)
        moved = jnp.moveaxis(q, a, -2 if is_vec else -1)
        if is_vec:
            return moved[..., :, None, :], moved[..., None, :, :]
        return moved[..., :, None], moved[..., None, :]

    rho, vel, p, e = prim
    rho_a, rho_b = pairwise(rho, False)
    vel_a, vel_b = pairwise(vel, True)
    p_a, p_b = pairwise(p, False)
    e_a, e_b = pairwise(e, False)
    rho_m = 0.5 * (rho_a + rho_b)
    vel_m = 0.5 * (vel_a + vel_b)
    p_m = 0.5 * (p_a + p_b)
    e_m = 0.5 * (e_a + e_b)
    vn = vel_m[..., direction]
    f_rho = rho_m * vn
    f_mom = _mom_flux(f_rho, vel_m, p_m, direction)
    f_e = f_rho * e_m + p_m * vn
    f_pair = jnp.concatenate([f_rho[..., None], f_mom, f_e[..., None]],
                             axis=-1)
    out = 2.0 * jnp.einsum("ij,...ijc->...ic", d_matrix, f_pair)
    return jnp.moveaxis(out, -2, _NODE_AXIS[direction] + out.ndim)


def _viscous_flux(u, grad_prim, nu_t, direction: int, mu: float,
                  prandtl: float, prandtl_turb: float):
    rho, vel, _, _ = _primitives(u)
    grad_v = grad_prim[..., 0:3, :]
    grad_t = grad_prim[..., 3, :]
    s_ij = 0.5 * (grad_v + jnp.swapaxes(grad_v, -1, -2))
    div_v = grad_v[..., 0, 0] + grad_v[..., 1, 1] + grad_v[..., 2, 2]
    mu_eff = mu + rho * nu_t
    third = (2.0 / 3.0) * mu_eff * div_v
    # column d of tau_ij = 2 mu_eff S_ij - (2/3) mu_eff div(v) delta_ij —
    # only the flux direction's column is needed, so no (3,3) tensor forms
    cols = []
    for i in range(3):
        c = 2.0 * mu_eff * s_ij[..., i, direction]
        if i == direction:
            c = c - third
        cols.append(c[..., None])
    tau_d = jnp.concatenate(cols, axis=-1)
    k_eff = _CP * (mu / prandtl + rho * nu_t / prandtl_turb)
    q_d = -k_eff * grad_t[..., direction]
    work = jnp.sum(tau_d * vel, axis=-1)
    zero = jnp.zeros_like(rho)
    return jnp.concatenate([zero[..., None], tau_d, (work - q_d)[..., None]],
                           axis=-1)


def navier_stokes_rhs_fused(
    u: jax.Array,
    cs_nodes: jax.Array,
    d_matrix: jax.Array,
    w: jax.Array,
    *,
    inv_w_end: tuple[float, float],
    jac: float,
    delta: float,
    mu: float,
    prandtl: float,
    prandtl_turb: float,
    forcing_a0: float,
    k_tke: float,
) -> jax.Array:
    """One fused periodic-HIT Navier-Stokes RHS evaluation — the mega-kernel
    oracle (kernels/rhs.py runs this exact function on its VMEM block).

    u: (..., Kx, Ky, Kz, n, n, n, 5) conservative state (any leading batch);
    cs_nodes: per-node Smagorinsky coefficient, shaped like u[..., 0];
    d_matrix: (n, n) Lagrange derivative matrix; w: (n,) GLL quadrature
    weights.  Scalars: `inv_w_end` endpoint inverse weights, `jac` the
    reference-to-physical scaling, `delta` the LES filter width, gas
    parameters and the Lundgren forcing controller (forcing_a0, k_tke).

    Pipeline (identical op order to cfd/solver.navier_stokes_rhs, the
    parity contract): primitive decode -> BR1 gradient of (v, T) ->
    Smagorinsky nu_t -> per-direction split-form Kennedy-Gruber volume +
    LLF surface + BR1 viscous divergence -> whole-box quadrature-mean
    forcing.  All math in float32; the result is cast to u.dtype (bf16
    in/out for the mixed-precision rollout).
    """
    out_dtype = u.dtype
    f32 = jnp.float32
    u = u.astype(f32)
    cs_nodes = cs_nodes.astype(f32)
    d_matrix = d_matrix.astype(f32)
    w2 = w.astype(f32) * 0.5  # reference [-1,1] -> unit mass

    rho, vel, p, temp = _primitives(u)
    e_spec = u[..., 4] / rho
    prim = (rho, vel, p, e_spec)
    q_prim = jnp.concatenate([vel, temp[..., None]], axis=-1)

    # BR1 gradient of (v, T): central interface averages, periodic wrap
    grads = []
    for d in range(3):
        vol = _deriv_along(q_prim, d_matrix, d)
        q_left, q_right = _neighbor_traces(q_prim, d)
        q_star_right = 0.5 * (q_left + q_right)
        lo, hi = _face_slices(q_prim, d)
        q_star_left = _roll(q_star_right, 1,
                            _ELEM_AXIS[d] + q_star_right.ndim + 1)
        g = _surface_lift(vol, q_star_right - hi, q_star_left - lo, d,
                          inv_w_end)
        grads.append(g * jac)
    grad_prim = jnp.stack(grads, axis=-1)

    # Smagorinsky eddy viscosity (paper Eq. 3)
    grad_v = grad_prim[..., 0:3, :]
    s_ij = 0.5 * (grad_v + jnp.swapaxes(grad_v, -1, -2))
    s_mag = jnp.sqrt(2.0 * jnp.sum(s_ij * s_ij, axis=(-1, -2)) + 1e-30)
    nu_t = (cs_nodes * delta) ** 2 * s_mag

    rhs = None
    for d in range(3):
        # advective: split-form volume + LLF surface
        vol_adv = _flux_differencing(prim, d_matrix, d)
        f_adv_nodes = _advective_flux(u, d)
        u_left, u_right = _neighbor_traces(u, d)
        f_star_adv = _lax_friedrichs(u_left, u_right, d)
        # viscous: standard derivative volume + central surface
        f_visc = _viscous_flux(u, grad_prim, nu_t, d, mu, prandtl,
                               prandtl_turb)
        vol_visc = _deriv_along(f_visc, d_matrix, d)
        fv_left, fv_right = _neighbor_traces(f_visc, d)
        f_star_visc = 0.5 * (fv_left + fv_right)

        vol = vol_adv - vol_visc
        f_star = f_star_adv - f_star_visc
        f_nodes = f_adv_nodes - f_visc
        lo, hi = _face_slices(f_nodes, d)
        f_star_left = _roll(f_star, 1, _ELEM_AXIS[d] + f_star.ndim + 1)
        div_d = _surface_lift(vol, f_star - hi, f_star_left - lo, d,
                              inv_w_end) * jac
        rhs = -div_d if rhs is None else rhs - div_d

    # Lundgren linear forcing + proportional TKE controller.  The whole mesh
    # is resident in the kernel block, so the global quadrature means are
    # computed in-pass.
    n_elem_total = u.shape[-7] * u.shape[-6] * u.shape[-5]
    mom = u[..., 1:4]
    mom_mean = jnp.einsum("...xyzijkc,i,j,k->...c", mom, w2, w2,
                          w2) / n_elem_total
    mom_fluct = mom - mom_mean[..., None, None, None, None, None, None, :]
    ke_density = 0.5 * jnp.sum(mom * vel, axis=-1, keepdims=True)
    k_now = jnp.einsum("...xyzijkc,i,j,k->...c", ke_density, w2, w2,
                       w2)[..., 0] / n_elem_total
    a_eff = forcing_a0 * jnp.clip(
        k_tke / jnp.maximum(k_now, 0.1 * k_tke), 0.0, 3.0)
    a_eff = a_eff[..., None, None, None, None, None, None]
    f_mom = a_eff[..., None] * mom_fluct
    f_e = jnp.sum(f_mom * vel, axis=-1, keepdims=True)
    forcing = jnp.concatenate([jnp.zeros_like(rhs[..., :1]), f_mom, f_e],
                              axis=-1)
    return (rhs + forcing).astype(out_dtype)


# --- flash attention ---------------------------------------------------------
def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Naive full-materialization GQA attention — the flash kernel's oracle.

    q: (B, Hq, Sq, D);  k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
    `window`: sliding-window size w — position i attends to [i-w+1, i]
    (count includes self), applied on ABSOLUTE positions assuming q occupies
    the last Sq positions of the Skv-long context (decode convention).
    `softcap`: gemma-2 logit soft-capping cap*tanh(x/cap).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    kg = jnp.repeat(k, group, axis=1)
    vg = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)  # absolute q positions
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    weights = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", weights, vg.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_k: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Flash-equivalent chunked attention in pure jnp (lax.scan over KV
    blocks, online softmax).  O(Sq * D) memory — the dry-run/TPU-free form
    with the same FLOP count and HBM traffic shape as the Pallas kernel."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    n_blocks = -(-skv // block_k)
    pad = n_blocks * block_k - skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kp.reshape(b, hkv, n_blocks, block_k, d)
    vb = vp.reshape(b, hkv, n_blocks, block_k, d)

    q32 = q.astype(jnp.float32)
    q_pos = jnp.arange(sq) + (skv - sq)

    def body(carry, blk):
        m, l, acc = carry
        k_blk, v_blk, start = blk  # (B, Hkv, bk, D), scalar
        k_blk = jnp.repeat(k_blk, group, axis=1).astype(jnp.float32)
        v_blk = jnp.repeat(v_blk, group, axis=1).astype(jnp.float32)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q32, k_blk) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = start + jnp.arange(block_k)
        mask = k_pos[None, :] < skv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # guard: rows with no valid key yet keep m=-inf -> exp(0)=1 row sums
        alpha = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m - m_new))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = alpha[..., None] * acc + jnp.einsum("bhqk,bhkd->bhqd", p, v_blk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    starts = jnp.arange(n_blocks) * block_k
    if unroll:  # dry-run calibration: no while loop in the HLO
        carry = (m0, l0, acc0)
        for i in range(n_blocks):
            carry, _ = body(carry, (kb[:, :, i], vb[:, :, i], starts[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, acc0),
            (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), starts),
        )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# --- gated linear recurrence (RWKV6 / SSM family) -----------------------------
def linear_scan(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array | None = None,
    s0: jax.Array | None = None,
    *,
    decay_before_read: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Exact sequential gated linear recurrence — the chunked kernel's oracle.

    Shapes: q, k, w: (B, T, dk);  v: (B, T, dv);  u: (dk,) or None;
    s0: (B, dk, dv) initial state or None.

    decay_before_read=False  (RWKV6):
        o_t = q_t @ (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    decay_before_read=True   (GLA / Mamba-like):
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
        o_t = q_t @ S_t

    Returns (o: (B, T, dv), s_final: (B, dk, dv)).  All math in f32.
    """
    b, t, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    q, k, v, w = (x.astype(f32) for x in (q, k, v, w))
    s0 = jnp.zeros((b, dk, dv), f32) if s0 is None else s0.astype(f32)

    def step(s, xs):
        qt, kt, vt, wt = xs  # (B, dk), (B, dk), (B, dv), (B, dk)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, dk, dv)
        if decay_before_read:
            s_new = wt[..., :, None] * s + kv
            o = jnp.einsum("bk,bkv->bv", qt, s_new)
        else:
            read = s + (u[None, :, None] * kv if u is not None else kv)
            o = jnp.einsum("bk,bkv->bv", qt, read)
            s_new = wt[..., :, None] * s + kv
        return s_new, o

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (q, k, v, w))
    s_final, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), s_final


def linear_scan_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array | None = None,
    s0: jax.Array | None = None,
    *,
    decay_before_read: bool = False,
    chunk: int = 64,
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel form of `linear_scan` in pure jnp (lax.scan over
    chunks, dense intra-chunk math) — the exact algorithm of the Pallas
    kernel, usable on any backend and fully differentiable.  This is the
    implementation the models use for training and the dry-run."""
    b, t, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    chunk = min(chunk, t)
    pad = (-t) % chunk
    q, k, v, w = (x.astype(f32) for x in (q, k, v, w))
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    tp = t + pad
    nc = tp // chunk
    qc, kc, vc, wc = (x.reshape(b, nc, chunk, -1).swapaxes(0, 1)
                      for x in (q, k, v, w))
    s_init = jnp.zeros((b, dk, dv), f32) if s0 is None else s0.astype(f32)
    mask = (jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
            if decay_before_read
            else jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), k=-1))

    def body(s, xs):
        qb, kb, vb, wb = xs  # (B, C, d*)
        cw = jnp.cumsum(jnp.log(jnp.maximum(wb, 1e-30)), axis=1)
        if decay_before_read:
            q_decay = jnp.exp(cw)
            pair = cw[:, :, None, :] - cw[:, None, :, :]
        else:
            cw_prev = jnp.concatenate([jnp.zeros_like(cw[:, :1]), cw[:, :-1]],
                                      axis=1)
            q_decay = jnp.exp(cw_prev)
            pair = cw_prev[:, :, None, :] - cw[:, None, :, :]
        pair = jnp.where(mask[None, :, :, None], pair, -jnp.inf)
        a = jnp.einsum("btd,bsd,btsd->bts", qb, kb, jnp.exp(pair))
        if not decay_before_read:
            diag = jnp.sum(qb * (u[None, None, :] * kb if u is not None else kb),
                           axis=-1)
            a = a + diag[:, :, None] * jnp.eye(chunk, dtype=f32)[None]
        o = jnp.einsum("bts,bsv->btv", a, vb) + jnp.einsum(
            "btk,bkv->btv", qb * q_decay, s)
        k_decay = jnp.exp(cw[:, -1:, :] - cw)
        s_new = jnp.exp(cw[:, -1])[..., None] * s + jnp.einsum(
            "btk,btv->bkv", kb * k_decay, vb)
        return s_new, o

    if unroll:  # dry-run calibration: no while loop in the HLO
        s_final = s_init
        outs = []
        for i in range(nc):
            s_final, o_i = body(s_final, (qc[i], kc[i], vc[i], wc[i]))
            outs.append(o_i)
        o = jnp.stack(outs, axis=0)
    else:
        s_final, o = jax.lax.scan(body, s_init, (qc, kc, vc, wc))
    o = o.swapaxes(0, 1).reshape(b, tp, dv)
    return o[:, :t], s_final

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantic contracts*: tests sweep shapes/dtypes and assert
allclose(kernel(interpret=True), ref).  They are also the implementations
used on non-TPU backends and inside the multi-pod dry-run (Pallas lowers for
TPU; the CPU dry-run must still produce a compilable, cost-analyzable HLO,
and the chunked/flash reference forms below have the same asymptotic
FLOP/byte behavior as the kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --- dg_derivative -----------------------------------------------------------
def dg_derivative3(u: jax.Array, d_matrix: jax.Array) -> tuple[jax.Array, ...]:
    """Fused 3-direction DGSEM derivative.

    u: (B, n, n, n, C) element batch; d_matrix: (n, n).
    Returns (du0, du1, du2) with du_d = derivative along intra-element axis d.
    """
    du0 = jnp.einsum("im,bmjkc->bijkc", d_matrix, u)
    du1 = jnp.einsum("jm,bimkc->bijkc", d_matrix, u)
    du2 = jnp.einsum("km,bijmc->bijkc", d_matrix, u)
    return du0, du1, du2


# --- smagorinsky -------------------------------------------------------------
def smagorinsky_nut(grad_v: jax.Array, cs: jax.Array, delta: float) -> jax.Array:
    """Fused strain-rate -> eddy-viscosity chain (paper Eq. 3).

    grad_v: (P, 3, 3) with grad_v[p, i, j] = d v_i / d x_j at point p.
    cs:     (P,) per-point Smagorinsky coefficient (element value broadcast).
    Returns nu_t: (P,) = (cs * delta)^2 * sqrt(2 S_ij S_ij).
    """
    s = 0.5 * (grad_v + jnp.swapaxes(grad_v, -1, -2))
    s_mag = jnp.sqrt(2.0 * jnp.sum(s * s, axis=(-1, -2)) + 1e-30)
    return (cs * delta) ** 2 * s_mag


# --- wall model --------------------------------------------------------------
def reichardt_uplus(y_plus, kappa: float = 0.41, xp=jnp):
    """Reichardt's composite law of the wall u+(y+): blends the viscous
    sublayer (u+ = y+), buffer layer and log law smoothly — valid at every
    y+, which is what lets one formula serve both the wall model and the
    reference profile at smoke-scale Reynolds numbers.  `xp` lets the same
    formula run under numpy for config-time reference profiles
    (cfd.channel re-exports this)."""
    return (xp.log1p(kappa * y_plus) / kappa
            + 7.8 * (1.0 - xp.exp(-y_plus / 11.0)
                     - (y_plus / 11.0) * xp.exp(-y_plus / 3.0)))


def wall_model_tau(u_par: jax.Array, rho_w: jax.Array, *, y_m: float,
                   nu: float, kappa: float = 0.41,
                   iters: int = 8) -> jax.Array:
    """tau_w = rho u_tau^2 by inverting u_par/u_tau = u+(y_m u_tau / nu).

    Geometrically-damped fixed point: in the viscous limit (u+ ~ y+) the
    damped map lands on the exact laminar stress mu u_par / y_m in one step,
    and in the log regime it contracts; `iters` iterations unroll into the
    jitted RHS.  Oracle for kernels/wall_model.py (identical op order).
    """
    f32 = jnp.float32
    up = u_par.astype(f32)
    u_tau = jnp.sqrt(nu * up / y_m + 1e-12)  # laminar initial guess
    for _ in range(iters):
        y_plus = y_m * u_tau / nu
        u_plus = jnp.maximum(reichardt_uplus(y_plus, kappa), 1e-6)
        u_tau = jnp.sqrt(u_tau * up / u_plus + 1e-14)
    return (rho_w.astype(f32) * u_tau**2).astype(u_par.dtype)


# --- flash attention ---------------------------------------------------------
def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Naive full-materialization GQA attention — the flash kernel's oracle.

    q: (B, Hq, Sq, D);  k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
    `window`: sliding-window size w — position i attends to [i-w+1, i]
    (count includes self), applied on ABSOLUTE positions assuming q occupies
    the last Sq positions of the Skv-long context (decode convention).
    `softcap`: gemma-2 logit soft-capping cap*tanh(x/cap).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    kg = jnp.repeat(k, group, axis=1)
    vg = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)  # absolute q positions
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    weights = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", weights, vg.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_k: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Flash-equivalent chunked attention in pure jnp (lax.scan over KV
    blocks, online softmax).  O(Sq * D) memory — the dry-run/TPU-free form
    with the same FLOP count and HBM traffic shape as the Pallas kernel."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    n_blocks = -(-skv // block_k)
    pad = n_blocks * block_k - skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kp.reshape(b, hkv, n_blocks, block_k, d)
    vb = vp.reshape(b, hkv, n_blocks, block_k, d)

    q32 = q.astype(jnp.float32)
    q_pos = jnp.arange(sq) + (skv - sq)

    def body(carry, blk):
        m, l, acc = carry
        k_blk, v_blk, start = blk  # (B, Hkv, bk, D), scalar
        k_blk = jnp.repeat(k_blk, group, axis=1).astype(jnp.float32)
        v_blk = jnp.repeat(v_blk, group, axis=1).astype(jnp.float32)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q32, k_blk) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = start + jnp.arange(block_k)
        mask = k_pos[None, :] < skv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # guard: rows with no valid key yet keep m=-inf -> exp(0)=1 row sums
        alpha = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m - m_new))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = alpha[..., None] * acc + jnp.einsum("bhqk,bhkd->bhqd", p, v_blk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    starts = jnp.arange(n_blocks) * block_k
    if unroll:  # dry-run calibration: no while loop in the HLO
        carry = (m0, l0, acc0)
        for i in range(n_blocks):
            carry, _ = body(carry, (kb[:, :, i], vb[:, :, i], starts[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, acc0),
            (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), starts),
        )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# --- gated linear recurrence (RWKV6 / SSM family) -----------------------------
def linear_scan(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array | None = None,
    s0: jax.Array | None = None,
    *,
    decay_before_read: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Exact sequential gated linear recurrence — the chunked kernel's oracle.

    Shapes: q, k, w: (B, T, dk);  v: (B, T, dv);  u: (dk,) or None;
    s0: (B, dk, dv) initial state or None.

    decay_before_read=False  (RWKV6):
        o_t = q_t @ (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    decay_before_read=True   (GLA / Mamba-like):
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
        o_t = q_t @ S_t

    Returns (o: (B, T, dv), s_final: (B, dk, dv)).  All math in f32.
    """
    b, t, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    q, k, v, w = (x.astype(f32) for x in (q, k, v, w))
    s0 = jnp.zeros((b, dk, dv), f32) if s0 is None else s0.astype(f32)

    def step(s, xs):
        qt, kt, vt, wt = xs  # (B, dk), (B, dk), (B, dv), (B, dk)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, dk, dv)
        if decay_before_read:
            s_new = wt[..., :, None] * s + kv
            o = jnp.einsum("bk,bkv->bv", qt, s_new)
        else:
            read = s + (u[None, :, None] * kv if u is not None else kv)
            o = jnp.einsum("bk,bkv->bv", qt, read)
            s_new = wt[..., :, None] * s + kv
        return s_new, o

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (q, k, v, w))
    s_final, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), s_final


def linear_scan_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array | None = None,
    s0: jax.Array | None = None,
    *,
    decay_before_read: bool = False,
    chunk: int = 64,
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel form of `linear_scan` in pure jnp (lax.scan over
    chunks, dense intra-chunk math) — the exact algorithm of the Pallas
    kernel, usable on any backend and fully differentiable.  This is the
    implementation the models use for training and the dry-run."""
    b, t, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    chunk = min(chunk, t)
    pad = (-t) % chunk
    q, k, v, w = (x.astype(f32) for x in (q, k, v, w))
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    tp = t + pad
    nc = tp // chunk
    qc, kc, vc, wc = (x.reshape(b, nc, chunk, -1).swapaxes(0, 1)
                      for x in (q, k, v, w))
    s_init = jnp.zeros((b, dk, dv), f32) if s0 is None else s0.astype(f32)
    mask = (jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
            if decay_before_read
            else jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), k=-1))

    def body(s, xs):
        qb, kb, vb, wb = xs  # (B, C, d*)
        cw = jnp.cumsum(jnp.log(jnp.maximum(wb, 1e-30)), axis=1)
        if decay_before_read:
            q_decay = jnp.exp(cw)
            pair = cw[:, :, None, :] - cw[:, None, :, :]
        else:
            cw_prev = jnp.concatenate([jnp.zeros_like(cw[:, :1]), cw[:, :-1]],
                                      axis=1)
            q_decay = jnp.exp(cw_prev)
            pair = cw_prev[:, :, None, :] - cw[:, None, :, :]
        pair = jnp.where(mask[None, :, :, None], pair, -jnp.inf)
        a = jnp.einsum("btd,bsd,btsd->bts", qb, kb, jnp.exp(pair))
        if not decay_before_read:
            diag = jnp.sum(qb * (u[None, None, :] * kb if u is not None else kb),
                           axis=-1)
            a = a + diag[:, :, None] * jnp.eye(chunk, dtype=f32)[None]
        o = jnp.einsum("bts,bsv->btv", a, vb) + jnp.einsum(
            "btk,bkv->btv", qb * q_decay, s)
        k_decay = jnp.exp(cw[:, -1:, :] - cw)
        s_new = jnp.exp(cw[:, -1])[..., None] * s + jnp.einsum(
            "btk,btv->bkv", kb * k_decay, vb)
        return s_new, o

    if unroll:  # dry-run calibration: no while loop in the HLO
        s_final = s_init
        outs = []
        for i in range(nc):
            s_final, o_i = body(s_final, (qc[i], kc[i], vc[i], wc[i]))
            outs.append(o_i)
        o = jnp.stack(outs, axis=0)
    else:
        s_final, o = jax.lax.scan(body, s_init, (qc, kc, vc, wc))
    o = o.swapaxes(0, 1).reshape(b, tp, dv)
    return o[:, :t], s_final

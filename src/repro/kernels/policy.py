"""Backend-driven kernel defaults — the single policy every entry point uses.

The Pallas kernels are the production path on TPU and an interpret-mode
oracle-check everywhere else.  Rather than each call site hardcoding
`interpret=True` (which silently de-optimizes real TPU runs) or configs
hardcoding `use_kernels=False` (which leaves the fused path dead on TPU),
both questions resolve here from `jax.default_backend()`:

  * `default_impl()`      "kernel" on TPU, "ref" elsewhere — what
                          `HITConfig`/`ChannelConfig` use when their
                          `use_kernels` field is left at None (auto).
  * `default_interpret()` False on TPU (compile for real), True elsewhere
                          (Pallas interprets; same numerics, any backend) —
                          what every kernel's `interpret=None` resolves to.

A `REPRO_KERNELS={kernel,ref,auto}` environment variable overrides the
*auto* resolution only — it retargets every `use_kernels=None` config and
`impl=None` call without editing code (benchmarks/CI forcing one column),
while an explicit config choice (`use_kernels=True/False`, `impl=...`)
still wins.  The variable is read at trace time: set it before the first
jit of a config, since cached programs keep the policy they traced with.

This module is a leaf (imports jax + os only) so the kernel modules
themselves can use it without cycling through the package __init__.
"""
from __future__ import annotations

import os

import jax

_ENV_VAR = "REPRO_KERNELS"
ACCEPTED = ("kernel", "ref", "auto")


def _env_override() -> str | None:
    raw = os.environ.get(_ENV_VAR, "")
    val = raw.strip().lower()
    if not val or val == "auto":
        return None
    if val in ("kernel", "ref"):
        return val
    raise ValueError(
        f"invalid {_ENV_VAR}={raw!r}: accepted values are "
        f"{', '.join(repr(a) for a in ACCEPTED)} ('auto' and unset both "
        "mean backend policy: kernels compiled on TPU, reference jnp "
        "elsewhere)")


# Fail at import, not at the first kernel dispatch deep inside a trace: a
# typo'd REPRO_KERNELS in a batch script should kill the job immediately
# with the accepted set, not after minutes of setup.
_env_override()


def default_impl() -> str:
    """Implementation the configs pick when `use_kernels` is None (auto):
    the REPRO_KERNELS env override if set, else the backend policy."""
    override = _env_override()
    if override is not None:
        return override
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def default_interpret() -> bool:
    """Pallas interpret mode: compiled on TPU, interpreted everywhere else."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """An explicit `interpret` wins; None defers to the backend policy."""
    return default_interpret() if interpret is None else interpret


def resolve_use_kernels(use_kernels: bool | None) -> bool:
    """Config `use_kernels` field: an explicit choice wins; None = policy.
    The shared resolver behind HITConfig/ChannelConfig `.kernels_enabled`."""
    return default_impl() == "kernel" if use_kernels is None else use_kernels

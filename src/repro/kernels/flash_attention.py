"""Pallas TPU kernel: blockwise-softmax (flash) attention forward.

Covers every attention variant the assigned architectures need:
  * GQA          (Hq = group * Hkv; the kv block is indexed at bh // group)
  * causal       masking with the decode convention (q occupies the LAST Sq
                 absolute positions of the Skv context)
  * sliding window (h2o-danube / hymba / gemma-2 local layers)
  * logit softcap  (gemma-2: cap * tanh(x / cap))

Grid: (B * Hq, Sq / block_q, Skv / block_k).  The last axis is sequential
on TPU ("arbitrary" dimension semantics): running max / sum / accumulator
live in VMEM scratch and the output block is written once on the final kv
step — the standard online-softmax flash schedule.  VMEM per grid step is
block_q*D (q) + 2*block_k*D (kv) + block_q*(D+2) (scratch): ~0.4 MiB at the
default 512/512 blocks with D=128 — far under budget, so blocks are sized
for MXU alignment (multiples of 128), not VMEM pressure.

Backward: see ops.flash_attention — custom_vjp with a recompute-from-ref
backward (the paper has no training-time attention contribution; fwd is
what serves the prefill/decode cells).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ._compat import tpu_compiler_params
from .policy import resolve_interpret

_NEG_INF = float("-inf")


def _kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None, softcap: float | None,
    block_q: int, block_k: int, n_kv_blocks: int, sq: int, skv: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (block_q, D)
    k = k_ref[0].astype(jnp.float32)  # (block_k, D)
    v = v_ref[0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    # absolute positions: q block rows / k block cols
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0) \
        + (skv - sq)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = k_pos < skv  # guard kv padding
    mask &= q_pos < skv  # guard q padding (rows beyond sq)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, _NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    # fully-masked-so-far rows keep m = -inf; guard the rescale factor
    alpha = jnp.exp(jnp.where(jnp.isneginf(m_prev), 0.0, m_prev - m_new))
    safe_m = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(jnp.where(mask, logits - safe_m[:, None], _NEG_INF))
    p = jnp.where(mask, p, 0.0)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1)
    acc_scr[...] = alpha[:, None] * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q",
                     "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention forward; contract identical to kernels.ref.mha.

    q: (B, Hq, Sq, D);  k, v: (B, Hkv, Skv, D).  Returns (B, Hq, Sq, D).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_k
    n_kv_blocks = skv_p // block_k
    grid = (b * hq, sq_p // block_q, n_kv_blocks)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_kv_blocks,
        sq=sq, skv=skv,
    )
    compiler_params = tpu_compiler_params(("parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, iq, ik, grp=group: (bh // grp, ik, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, iq, ik, grp=group: (bh // grp, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
        name="flash_attention_fwd",
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(qf, kf, vf)
    out = out[:, :sq] if pad_q else out
    return out.reshape(b, hq, sq, d)

"""Pallas TPU kernel: batched Reichardt law-of-the-wall fixed-point inversion.

The channel scenario's hottest per-step serial chain: every RK stage inverts
u_par / u_tau = u+(y_m u_tau / nu) at every wall face column to get the
modeled wall stress tau_w = rho u_tau^2 (cfd/channel.py).  The inversion is
`iters` dependent sqrt/log1p/exp rounds per point — pure VPU transcendental
work with zero reuse between points, so XLA's unfused form re-reads u_par and
the iterate from HBM between rounds.  The fused kernel keeps the whole
fixed-point chain in VMEM: one read of (u_par, rho_w), one write of tau_w,
`iters` rounds in registers (2 floats moved per point total).

Layout: point-flattened (P,) wall-face columns — callers flatten whatever
`(B, n_wall_elems, face_dofs)` batch they carry; grid over P blocks.  The
scalar wall geometry (y_m, nu, kappa) and the iteration budget are
compile-time constants.  Matches kernels.ref.wall_model_tau (the oracle;
identical op order, so the float32 paths agree bit-for-bit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .policy import resolve_interpret
from .ref import reichardt_uplus


def _kernel(upar_ref, rho_ref, tau_ref, *, y_m: float, nu: float,
            kappa: float, iters: int):
    u_par = upar_ref[...].astype(jnp.float32)  # (Pb,)
    rho_w = rho_ref[...].astype(jnp.float32)   # (Pb,)
    # geometrically-damped fixed point, laminar initial guess (exact in the
    # viscous sublayer, contracting in the log layer) — cfd/channel.py docs
    u_tau = jnp.sqrt(nu * u_par / y_m + 1e-12)
    for _ in range(iters):
        y_plus = y_m * u_tau / nu
        u_plus = jnp.maximum(reichardt_uplus(y_plus, kappa), 1e-6)
        u_tau = jnp.sqrt(u_tau * u_par / u_plus + 1e-14)
    tau_ref[...] = (rho_w * u_tau**2).astype(tau_ref.dtype)


@functools.partial(jax.jit, static_argnames=("y_m", "nu", "kappa", "iters",
                                             "block_p", "interpret"))
def wall_model_tau(
    u_par: jax.Array,
    rho_w: jax.Array,
    *,
    y_m: float,
    nu: float,
    kappa: float = 0.41,
    iters: int = 8,
    block_p: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """tau_w for an arbitrary batch of wall-face points.

    u_par, rho_w: any (broadcast-identical) shape — tangential matching-point
    speed and wall density; flattened to (P,) internally.  Returns tau_w with
    the input shape.  Matches kernels.ref.wall_model_tau.
    """
    shape = u_par.shape
    up = u_par.reshape(-1)
    rw = rho_w.reshape(-1)
    p = up.shape[0]
    block_p = min(block_p, p)
    pad = (-p) % block_p
    if pad:
        # pad with 1s: the fixed point stays finite for any positive input
        up = jnp.pad(up, (0, pad), constant_values=1.0)
        rw = jnp.pad(rw, (0, pad), constant_values=1.0)
    pp = p + pad
    tau = pl.pallas_call(
        functools.partial(_kernel, y_m=y_m, nu=nu, kappa=kappa, iters=iters),
        grid=(pp // block_p,),
        in_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), u_par.dtype),
        interpret=resolve_interpret(interpret),
        name="wall_model_tau",
    )(up, rw)
    return (tau[:p] if pad else tau).reshape(shape)

"""Pallas API-drift shims shared by the TPU kernels.

jax renamed `pltpu.TPUCompilerParams` to `pltpu.CompilerParams` (and has
moved it between modules before); the kernels only use it for grid
dimension semantics, which are a pure scheduling hint.  Resolve whichever
name the installed jax exposes and degrade to "no hint" rather than pinning
a jax version.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu


def tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    """`compiler_params` value for `pl.pallas_call`, or None if the installed
    jax has neither spelling (the call then runs with compiler defaults)."""
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is None:
            continue
        try:
            return cls(dimension_semantics=tuple(dimension_semantics))
        except TypeError:  # field renamed/removed in a future drift
            continue
    return None

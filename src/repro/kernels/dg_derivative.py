"""Pallas TPU kernel: fused three-direction DGSEM derivative.

The DGSEM volume term applies the (n x n) Lagrange derivative matrix D along
each of the three intra-element node axes of every element — three tiny
contractions over a huge element batch (the solver's dominant FLOP term,
paper Sec. 3.2 / FLEXI).

Arithmetic intensity per point is low (3n MACs vs 4 channel floats moved),
so the win on TPU is HBM traffic, not MXU utilization: computing all three
directions in ONE pass over u reads u once instead of three times
(16 B/point moved instead of 24 B/point -> 1.5x less traffic on the
memory-bound term; see EXPERIMENTS.md §Perf).

Layout: u is flattened to (B, n, n, n, C) with B = batch * K^3 elements.
Each grid step processes a block of `block_b` elements held in VMEM; the
three contractions are MXU matmuls over reshaped views:

    d0 : (n, n) @ (B_blk, n, [n n C])   contracting node axis 0
    d1 : per-i0 (n, n) @ (..., n, [n C])
    d2 : (..., [n n], n, C) with D applied on the third node axis

D lives in VMEM as a whole (n <= 16: at most 1 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .policy import resolve_interpret


def _kernel(u_ref, d_ref, du0_ref, du1_ref, du2_ref):
    u = u_ref[...]  # (Bb, n, n, n, C)
    d = d_ref[...]  # (n, n)
    bb, n, _, _, c = u.shape
    f32 = jnp.float32
    u32 = u.astype(f32)
    d32 = d.astype(f32)

    # direction 0: contract first node axis -> (i <- m): D[i,m] u[b,m,j,k,c]
    u_m = u32.reshape(bb, n, n * n * c)             # (Bb, m, X)
    du0 = jnp.einsum("im,bmx->bix", d32, u_m)
    du0_ref[...] = du0.reshape(u.shape).astype(u.dtype)

    # direction 1: contract second node axis
    u_m = u32.reshape(bb * n, n, n * c)             # (Bb*i0, m, X)
    du1 = jnp.einsum("jm,bmx->bjx", d32, u_m)
    du1_ref[...] = du1.reshape(u.shape).astype(u.dtype)

    # direction 2: contract third node axis
    u_m = u32.reshape(bb * n * n, n, c)             # (Bb*i0*i1, m, C)
    du2 = jnp.einsum("km,bmc->bkc", d32, u_m)
    du2_ref[...] = du2.reshape(u.shape).astype(u.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dg_derivative3(
    u: jax.Array,
    d_matrix: jax.Array,
    *,
    block_b: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (du/dxi_0, du/dxi_1, du/dxi_2) for an element batch.

    u: (B, n, n, n, C);  d_matrix: (n, n).  Matches kernels.ref.dg_derivative3.
    """
    b, n, _, _, c = u.shape
    block_b = min(block_b, b)
    pad = (-b) % block_b
    u_p = jnp.pad(u, ((0, pad),) + ((0, 0),) * 4) if pad else u
    bp = b + pad
    grid = (bp // block_b,)
    blk = (block_b, n, n, n, c)
    spec = pl.BlockSpec(blk, lambda i: (i, 0, 0, 0, 0))
    out_shape = jax.ShapeDtypeStruct((bp, n, n, n, c), u.dtype)
    du0, du1, du2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=[spec, spec, spec],
        out_shape=[out_shape] * 3,
        interpret=resolve_interpret(interpret),
        name="dg_derivative3",
    )(u_p, d_matrix)
    if pad:
        du0, du1, du2 = du0[:b], du1[:b], du2[:b]
    return du0, du1, du2

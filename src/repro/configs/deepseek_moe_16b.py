"""deepseek-moe-16b [moe] — fine-grained MoE with shared experts.

arXiv:2401.06066 (DeepSeekMoE).  28L, d_model 2048, 16 heads (MHA: kv=16,
head_dim 128), 64 routed experts top-6 + 2 shared (expert d_ff 1408),
first layer dense (d_ff 10944), vocab 102400.  DeepSeek-v1 routing: top-k
gates are NOT renormalized.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    head_dim=128,
    mixer="attn",
    ffn="moe",
    norm="rmsnorm",
    rope=True,
    rope_theta=10_000.0,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_dense=10944,
    first_dense_layers=1,
    norm_topk=False,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, kv_heads=4, head_dim=16,
        d_ff=48, d_ff_dense=128, n_experts=8, top_k=2, vocab=497,
        moe_group_size=64, loss_chunk=32, attn_block_k=32)

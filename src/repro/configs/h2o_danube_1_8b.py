"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

arXiv:2401.16818.  24L, d_model 2560, 32 heads GQA kv=8 (head_dim 80),
d_ff 6912 (SwiGLU), vocab 32000, 4096-token sliding window on every layer —
the window bounds the KV cache, which qualifies the long_500k cell.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    kv_heads=8,
    d_ff=6912,
    vocab=32000,
    head_dim=80,
    mixer="attn",
    ffn="swiglu",
    norm="rmsnorm",
    rope=True,
    rope_theta=10000.0,
    window=4096,
    window_pattern=0,  # SWA on every layer
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
        d_ff=160, vocab=493, window=16, loss_chunk=32, attn_block_k=32)

"""starcoder2-7b [dense] — GQA + RoPE code model (arXiv:2402.19173).

32L, d_model 4608, 36 heads GQA kv=4 (head_dim 128), d_ff 18432 (plain GELU
MLP), vocab 49152.  StarCoder2 uses LayerNorm and biases on attention/MLP
projections; per the assignment's feature list the attention is full causal
(no sliding window), which is also what rules this arch out of long_500k.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    kv_heads=4,
    d_ff=18432,
    vocab=49152,
    head_dim=128,
    mixer="attn",
    ffn="gelu_mlp",
    norm="layernorm",
    attn_bias=True,
    mlp_bias=True,
    rope=True,
    rope_theta=100_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
        d_ff=192, vocab=501, loss_chunk=32, attn_block_k=32)

"""gemma2-27b [dense] — local/global alternating attention, logit softcaps.

arXiv:2408.00118.  46L, d_model 4608, 32 heads GQA kv=16 (head_dim 128),
d_ff 36864 (GeGLU), vocab 256000.  Gemma-2 specifics honored: sandwich
(post) norms, (1+scale) RMSNorm, sqrt(d_model) embedding scale, tied
embeddings, attn softcap 50, final-logit softcap 30, query scale
(d_model/n_heads)^-1/2 = 144^-1/2, 4096-token sliding window on every other
layer (odd layers global).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    kv_heads=16,
    d_ff=36864,
    vocab=256_000,
    head_dim=128,
    mixer="attn",
    ffn="geglu",
    norm="rmsnorm",
    norm_scale_plus_one=True,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    logit_softcap=30.0,
    attn_softcap=50.0,
    attn_scale=(4608 / 32) ** -0.5,
    rope=True,
    rope_theta=10000.0,
    window=4096,
    window_pattern=2,  # layer i global iff i % 2 == 1
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
        d_ff=256, vocab=509, window=16, attn_scale=16.0 ** -0.5,
        loss_chunk=32, attn_block_k=32)

"""whisper-tiny [audio] — encoder-decoder speech backbone.

arXiv:2212.04356 (unverified tier).  4 encoder + 4 decoder layers,
d_model 384, 6 heads (kv=6, head_dim 64), d_ff 1536 (GELU MLP),
vocab 51865, LayerNorm + biases, learned positions, tied decoder head.

The conv1d audio frontend is a STUB per the brief: `input_specs()` supplies
precomputed frame embeddings (B, 1500, 384).  The decode_32k / train_4k
decoder lengths are mechanical per the assigned shape set (the released
model decodes <= 448 positions); the learned decoder position table is
sized to the largest assigned cell.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers
    encoder_layers=4,
    d_model=384,
    n_heads=6,
    kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    mixer="attn",
    ffn="gelu_mlp",
    norm="layernorm",
    attn_bias=True,
    mlp_bias=True,
    tie_embeddings=True,
    rope=False,
    max_source_positions=1500,
    max_positions=32768,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
        kv_heads=4, head_dim=16, d_ff=128, vocab=479,
        max_source_positions=24, max_positions=128,
        loss_chunk=32, attn_block_k=32)

"""command-r-35b [dense] — parallel-block decoder, no biases.

hf:CohereForAI/c4ai-command-r-v01 (unverified tier).  40L, d_model 8192,
64 heads GQA kv=8 (head_dim 128), d_ff 22528 (SwiGLU), vocab 256000.
Cohere specifics: attention and FFN branch from the SAME pre-norm
(parallel block), bias-free LayerNorm, tied embeddings, rope_theta 8e6.
(The released model's 0.0625 logit_scale multiplier is folded into the
embedding init here — noted, not modeled separately.)
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=22528,
    vocab=256_000,
    head_dim=128,
    mixer="attn",
    ffn="swiglu",
    norm="layernorm_nobias",
    parallel_block=True,
    tie_embeddings=True,
    rope=True,
    rope_theta=8_000_000.0,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=8, kv_heads=2, head_dim=16,
        d_ff=160, vocab=499, loss_chunk=32, attn_block_k=32)

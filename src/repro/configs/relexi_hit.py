"""The paper's own configurations (Table 1): HIT LES at 24 and 32 DOF.

    name    N  #Elems  #DOF    k_max  alpha
    24 DOF  5  4^3     13,824  9      0.4
    32 DOF  7  4^3     32,768  12     0.2

All configs leave `use_kernels` at None (auto): the Pallas solver kernels
are on and compiled whenever `jax.default_backend() == "tpu"` and fall back
to the pure-jnp reference elsewhere (kernels.default_impl()); pass
`use_kernels=True/False` to force either path.
"""
from ..cfd.solver import HITConfig

HIT24 = HITConfig(n_poly=5, n_elem=4, k_max=9, alpha=0.4)
HIT32 = HITConfig(n_poly=7, n_elem=4, k_max=12, alpha=0.2)


def reduced(use_kernels: bool | None = None) -> HITConfig:
    """CPU-friendly smoke scale: N=3, 2^3 elements, short episodes."""
    return HITConfig(n_poly=3, n_elem=2, k_max=3, alpha=0.4, t_end=0.3,
                     dt_rl=0.1, k_peak=2.0, k_eta=8.0,
                     use_kernels=use_kernels)

"""Assigned input-shape set (identical for all LM-family architectures).

``decode_*`` / ``long_*`` lower `serve_step` (one new token against a KV
cache of seq_len), NOT `train_step`.  `long_500k` requires sub-quadratic
attention and only runs for SSM / hybrid / SWA-bounded architectures — the
skip logic lives in `cells()` and every skip carries its reason into the
dry-run and roofline tables.
"""
from __future__ import annotations

import dataclasses

from ..models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def long_context_ok(cfg: ArchConfig) -> tuple[bool, str]:
    """Can this architecture serve a 500k-token context?"""
    if cfg.mixer == "rwkv":
        return True, "attention-free (O(1) state)"
    if cfg.mixer == "attn+mamba":
        return True, "hybrid: SWA + SSM state bound the context"
    if cfg.window and not cfg.window_pattern and not cfg.is_encdec:
        return True, f"sliding window {cfg.window} bounds the KV cache"
    if cfg.is_encdec:
        return False, "enc-dec: 500k decoder positions out of family (30s receptive field)"
    if cfg.window_pattern:
        return False, "global full-attention layers -> O(S^2)/O(S) KV at 500k"
    return False, "pure full attention -> unbounded KV at 500k"


def cells(cfg: ArchConfig) -> list[tuple[ShapeConfig, bool, str]]:
    """All four (shape, runnable, reason) cells for an architecture."""
    out = []
    for shape in SHAPES.values():
        if shape.name == "long_500k":
            ok, reason = long_context_ok(cfg)
            out.append((shape, ok, reason))
        else:
            out.append((shape, True, ""))
    return out

"""llava-next-mistral-7b [vlm] — Mistral-7B backbone + anyres vision prefix.

hf:llava-hf/llava-v1.6-mistral-7b-hf (unverified tier).  Backbone: 32L,
d_model 4096, 32 heads GQA kv=8 (head_dim 128), d_ff 14336 (SwiGLU),
vocab 32000, rope_theta 1e6, full attention (mistral-v0.2 base, no SWA).

The anyres tiling frontend is a STUB per the brief: `input_specs()` feeds
precomputed CLIP patch embeddings (B, 576, 1024); the in-model part — the
2-layer GELU mm-projector — IS implemented (models/lm.py `projector`), and
the projected image tokens are prepended to the text sequence.  Cell
`seq_len` counts the TOTAL sequence (image prefix + text).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    mixer="attn",
    ffn="swiglu",
    norm="rmsnorm",
    rope=True,
    rope_theta=1_000_000.0,
    vision_dim=1024,
    vision_tokens=576,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
        d_ff=160, vocab=491, vision_dim=32, vision_tokens=16,
        loss_chunk=32, attn_block_k=32)

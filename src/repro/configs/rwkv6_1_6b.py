"""rwkv6-1.6b [ssm] — Finch: attention-free linear RNN with data-dependent
decay (arXiv:2404.05892, unverified tier).

24L, d_model 2048, d_ff 7168 (channel-mix), vocab 65536, head_dim 64 ->
32 WKV heads.  O(1)-state decode is what qualifies the long_500k cell.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # d_model / head_dim WKV heads
    kv_heads=32,
    d_ff=7168,
    vocab=65536,
    head_dim=64,
    mixer="rwkv",
    ffn="rwkv_cmix",
    norm="layernorm",
    rope=False,
    rwkv_lora=32,
    rwkv_decay_lora=64,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, kv_heads=4, head_dim=16,
        d_ff=128, vocab=487, rwkv_lora=8, rwkv_decay_lora=8,
        loss_chunk=32, scan_chunk=8)

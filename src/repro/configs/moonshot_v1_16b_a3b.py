"""moonshot-v1-16b-a3b [moe] — Moonlight-16B-A3B-style fine-grained MoE.

hf:moonshotai/Moonlight-16B-A3B.  48L, d_model 2048, 16 heads (kv=16,
head_dim 128), 64 routed experts top-6 + 2 shared (expert d_ff 1408),
first layer dense (d_ff 11264), vocab 163840, renormalized top-k gates.
Per the assignment the attention is GQA (the released model's MLA variant
is out of the assigned scope — noted in DESIGN.md).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=1408,
    vocab=163_840,
    head_dim=128,
    mixer="attn",
    ffn="moe",
    norm="rmsnorm",
    rope=True,
    rope_theta=50_000.0,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_dense=11264,
    first_dense_layers=1,
    norm_topk=True,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, kv_heads=4, head_dim=16,
        d_ff=48, d_ff_dense=128, n_experts=8, top_k=2, vocab=503,
        moe_group_size=64, loss_chunk=32, attn_block_k=32)

"""Config registry: `--arch <id>` resolution for launcher / dry-run / tests.

One module per assigned architecture (exact published configs) plus the
paper's own HIT LES configurations.  `get(name)` returns the full
ArchConfig; `get_reduced(name)` the smoke-test scale of the same family.
"""
from __future__ import annotations

import importlib

from ..models.config import ArchConfig
from .shapes import SHAPES, ShapeConfig, cells, long_context_ok

# hymba last: its dry-run calibration (group size 8) has the slowest compiles
_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "starcoder2-7b": "starcoder2_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "command-r-35b": "command_r_35b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-tiny": "whisper_tiny",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_NAMES = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f".{_MODULES[name]}", __package__)


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ArchConfig:
    return _module(name).reduced()


def all_configs() -> dict[str, ArchConfig]:
    return {n: get(n) for n in ARCH_NAMES}


__all__ = ["ARCH_NAMES", "SHAPES", "ShapeConfig", "cells", "long_context_ok",
           "get", "get_reduced", "all_configs"]

"""hymba-1.5b [hybrid] — parallel attention + Mamba heads per layer.

arXiv:2411.13676 (NVIDIA Hymba).  32L, d_model 1600, 25 query heads with
GQA kv=5 (head_dim 64), d_ff 5504, vocab 32001, ssm_state 16.

Simplifications (DESIGN.md §Arch-applicability): Hymba's meta-tokens are
omitted, and its {first, middle, last}-layer global attention becomes a
global-every-8th-layer pattern so the layer stack scans uniformly; all other
layers use the paper's sliding window.  The SSM branch carries long-range
context, which is what qualifies the long_500k cell.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    mixer="attn+mamba",
    ffn="swiglu",
    norm="rmsnorm",
    rope=True,
    rope_theta=10000.0,
    window=1024,
    window_pattern=8,   # layer i global iff i % 8 == 7 (see module docstring)
    ssm_state=16,
    d_conv=4,
)


def reduced() -> ArchConfig:
    """Smoke-test scale: same family, tiny dimensions."""
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=5, kv_heads=1, head_dim=16,
        d_ff=128, vocab=257, window=16, window_pattern=8, ssm_state=4,
        moe_group_size=64, loss_chunk=32, scan_chunk=8, attn_block_k=32)

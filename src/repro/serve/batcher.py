"""Request batcher: heterogeneous per-scenario queues -> fixed compiled
shapes.

Serving traffic arrives one observation at a time, from many callers,
across scenarios with incompatible obs shapes — but every jitted program
wants a fixed batch shape, and each distinct shape costs a compile.  The
batcher bridges the two with a bucket ladder (host-side; nothing here is
traced):

  * requests enqueue FIFO per scenario, each stamped with a monotonically
    increasing uid (the global arrival order) and a recycled slot id;
  * `flush()` drains every queue into `PendingBatch`es: each batch's rows
    are the pending requests IN ARRIVAL ORDER, padded up to the smallest
    bucket that fits (`bucket_for` — a pure function of the pending count,
    so bucket selection is deterministic), with queues longer than the
    largest bucket chunked into max-bucket batches first;
  * padding rows repeat the batch's LAST real row — in-distribution
    values, and the consumer slices `[:n_valid]` so they can never reach a
    caller (pinned by tests/test_serve.py's hypothesis properties);
  * slot recycling: a bounded pool of `max_slots` streaming slots; submit
    acquires the lowest free slot, `release` (called by the service once a
    result is delivered) returns it.  A full pool refuses new requests
    loudly instead of queueing unboundedly — the backpressure contract.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable

import numpy as np

# Powers of two up to 16: small enough that the whole ladder compiles in
# seconds at reduced shapes, doubling so any pending count wastes < half a
# batch of padding.  Callers tune per deployment (perf_serve.py sweeps it).
DEFAULT_BUCKETS = (1, 2, 4, 8, 16)


def bucket_for(n: int, buckets: tuple[int, ...] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n (counts above the largest bucket are chunked by
    the batcher before this is asked).  Pure and deterministic."""
    if n <= 0:
        raise ValueError(f"bucket_for needs a positive count, got {n}")
    for b in buckets:
        if b >= n:
            return b
    raise ValueError(f"pending count {n} exceeds the largest bucket "
                     f"{buckets[-1]}; chunk first")


@dataclasses.dataclass(frozen=True)
class _Request:
    uid: int
    slot: int
    obs: np.ndarray


@dataclasses.dataclass(frozen=True)
class PendingBatch:
    """One compiled-shape unit of work: `obs` is (bucket, *obs_shape) with
    rows [0:n_valid] the real requests (arrival order) and the rest
    padding; `uids`/`slots` identify the real rows only."""

    scenario: str
    uids: tuple[int, ...]
    slots: tuple[int, ...]
    obs: np.ndarray
    n_valid: int

    @property
    def bucket(self) -> int:
        return self.obs.shape[0]


class RequestBatcher:
    """FIFO per-scenario request queues with bucket padding + slot pool."""

    def __init__(self, scenarios: Iterable[str], *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 max_slots: int = 64):
        self.scenarios = tuple(scenarios)
        if not self.scenarios:
            raise ValueError("batcher needs at least one scenario")
        if list(buckets) != sorted(set(buckets)) or buckets[0] < 1:
            raise ValueError(f"buckets must be strictly increasing positive "
                             f"ints, got {buckets}")
        self.buckets = tuple(int(b) for b in buckets)
        self.max_slots = int(max_slots)
        self._queues: dict[str, list[_Request]] = {n: []
                                                   for n in self.scenarios}
        self._free_slots: list[int] = list(range(self.max_slots))
        heapq.heapify(self._free_slots)   # lowest free slot first: recycling
        self._next_uid = 0                # is deterministic and observable

    # --- introspection --------------------------------------------------------
    @property
    def n_pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def n_free_slots(self) -> int:
        return len(self._free_slots)

    # --- submit / release -----------------------------------------------------
    def submit(self, scenario: str, obs: np.ndarray) -> int:
        """Enqueue one observation; returns the request uid.  Refuses
        unknown scenarios and an exhausted slot pool."""
        if scenario not in self._queues:
            raise KeyError(f"unknown scenario {scenario!r}; serving "
                           f"{self.scenarios}")
        if not self._free_slots:
            raise RuntimeError(
                f"no free request slots (max_slots={self.max_slots}); "
                "flush pending work before submitting more")
        slot = heapq.heappop(self._free_slots)
        uid = self._next_uid
        self._next_uid += 1
        self._queues[scenario].append(
            _Request(uid=uid, slot=slot, obs=np.asarray(obs)))
        return uid

    def release(self, slot: int) -> None:
        """Return a completed request's slot to the pool."""
        if not 0 <= slot < self.max_slots or slot in self._free_slots:
            raise ValueError(f"slot {slot} is not an outstanding slot")
        heapq.heappush(self._free_slots, slot)

    # --- flush ----------------------------------------------------------------
    def _pad(self, scenario: str, chunk: list[_Request]) -> PendingBatch:
        bucket = bucket_for(len(chunk), self.buckets)
        rows = [r.obs for r in chunk]
        rows.extend([rows[-1]] * (bucket - len(chunk)))
        return PendingBatch(
            scenario=scenario,
            uids=tuple(r.uid for r in chunk),
            slots=tuple(r.slot for r in chunk),
            obs=np.stack(rows, axis=0),
            n_valid=len(chunk))

    def flush(self) -> list[PendingBatch]:
        """Drain every queue into padded batches, scenarios in declared
        order, each queue chunked FIFO (full max-bucket chunks first, then
        one bucket-rounded remainder)."""
        batches: list[PendingBatch] = []
        cap = self.buckets[-1]
        for scenario in self.scenarios:
            queue = self._queues[scenario]
            self._queues[scenario] = []
            for start in range(0, len(queue), cap):
                batches.append(self._pad(scenario, queue[start:start + cap]))
        return batches

"""The serving dispatch layer: scenario-routed, bucket-compiled inference.

`ControllerService` is what a solver talks to: submit observations by
registered scenario name, flush, get greedy actions back.  Internals:

  * ONE jitted program per (scenario, batch-bucket) — `serve_step` below,
    compiled lazily the first time a bucket shape is dispatched and cached
    by jit's shape cache thereafter (the service's `_step` wrapper is the
    handle the trace auditor certifies against);
  * the deterministic greedy-action path: `multitask.actor_mean`, the
    EXACT function the training-time deterministic evaluation uses
    (`core/rollout.py` with `deterministic=True`), so served actions are
    bit-identical to training-time policy evaluation at fp32 — pinned by
    tests/test_serve.py;
  * a donated on-device telemetry buffer per scenario ([requests_served,
    batches_served] int32): the counter updates in place every dispatch
    (the same donation contract as the broker's ring pushes), and the hot
    path never reads it back — `stats()` drains it on demand;
  * padding discipline: the batcher pads rows up to the bucket, the
    service slices every output back to `[:n_valid]` before a caller sees
    it — padding rows can never leak.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..fleet import multitask
from .batcher import DEFAULT_BUCKETS, PendingBatch, RequestBatcher
from .loader import LoadedPolicy, load_policy


def serve_step(params: dict, mcfg: multitask.MultiTaskConfig, name: str,
               obs: jax.Array, n_valid: jax.Array, stats: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One compiled serving dispatch for scenario `name` at one bucket shape.

    obs: (bucket, E, *spatial, C) padded observation batch.
    Returns (actions (bucket, E), values (bucket,), stats') — actions via
    the deterministic greedy path (`actor_mean`), values from the critic
    head, and the telemetry counter advanced by (n_valid requests, 1
    batch).  `stats` is donated at the jit boundary: the counter updates
    in place, never copied.
    """
    actions = multitask.actor_mean(params, mcfg, name, obs)
    values = multitask.value(params, mcfg, name, obs)
    stats = stats.at[0].add(n_valid.astype(stats.dtype)).at[1].add(1)
    return actions, values, stats


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One request's answer: the greedy per-element action and the critic's
    value estimate for the submitted observation."""

    uid: int
    scenario: str
    action: np.ndarray
    value: float


class ControllerService:
    """Batched low-latency serving front-end over one trained policy tree."""

    def __init__(self, params: dict, mcfg: multitask.MultiTaskConfig, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 max_slots: int = 64):
        self.params = params
        self.mcfg = mcfg
        self.batcher = RequestBatcher(mcfg.names, buckets=buckets,
                                      max_slots=max_slots)
        # the (scenario, bucket) -> compiled-program map IS this wrapper's
        # jit cache: mcfg/name are static, so each (name, bucket shape)
        # pair traces exactly once; the stats buffer (argnum 5) is donated
        self._step = jax.jit(serve_step, static_argnums=(1, 2),
                             donate_argnums=(5,))
        self._stats = {name: jnp.zeros((2,), jnp.int32)
                       for name in mcfg.names}

    @classmethod
    def from_policy(cls, policy: LoadedPolicy, **kwargs) -> "ControllerService":
        return cls(policy.params, policy.mcfg, **kwargs)

    @property
    def scenarios(self) -> tuple[str, ...]:
        return self.mcfg.names

    # --- request path ---------------------------------------------------------
    def submit(self, scenario: str, obs: np.ndarray) -> int:
        """Enqueue one observation (E, *spatial, C); returns the uid its
        result will carry.  Shape-checked here so a malformed request fails
        at submit time, not inside a compiled program."""
        head = self.mcfg.head(scenario)   # raises on unknown scenarios
        want = (head.n_elements, *head.spatial, head.channels)
        obs = np.asarray(obs, dtype=np.float32)
        if obs.shape != want:
            raise ValueError(
                f"{scenario!r} observation shape {obs.shape} != declared "
                f"{want}")
        return self.batcher.submit(scenario, obs)

    def _dispatch(self, batch: PendingBatch) -> tuple[jax.Array, jax.Array]:
        obs = jnp.asarray(batch.obs)
        actions, values, self._stats[batch.scenario] = self._step(
            self.params, self.mcfg, batch.scenario, obs,
            jnp.asarray(batch.n_valid, jnp.int32),
            self._stats[batch.scenario])
        return actions, values

    def flush(self) -> dict[int, ServeResult]:
        """Serve everything pending: batch, dispatch, slice padding, free
        the slots.  Returns {uid: ServeResult}."""
        results: dict[int, ServeResult] = {}
        for batch in self.batcher.flush():
            actions, values = self._dispatch(batch)
            acts = np.asarray(actions[: batch.n_valid])
            vals = np.asarray(values[: batch.n_valid])
            for i, (uid, slot) in enumerate(zip(batch.uids, batch.slots)):
                results[uid] = ServeResult(
                    uid=uid, scenario=batch.scenario, action=acts[i],
                    value=float(vals[i]))
                self.batcher.release(slot)
        return results

    def serve_batch(self, scenario: str, obs_batch: np.ndarray) -> np.ndarray:
        """One-shot convenience: serve (B, E, *spatial, C) rows, returning
        (B, E) greedy actions in row order (B may exceed the largest bucket
        — the batcher chunks)."""
        uids = [self.submit(scenario, row) for row in np.asarray(obs_batch)]
        results = self.flush()
        return np.stack([results[uid].action for uid in uids], axis=0)

    # --- telemetry ------------------------------------------------------------
    def stats(self) -> dict[str, dict[str, int]]:
        """Host read of the per-scenario serving counters."""
        return {name: {"requests": int(c[0]), "batches": int(c[1])}
                for name, c in jax.device_get(self._stats).items()}


def load_service(checkpoint_dir: str, step: int | None = None, *,
                 mesh=None, buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 max_slots: int = 64, **load_kwargs) -> ControllerService:
    """checkpoint directory -> ready service (loader + dispatch in one)."""
    policy = load_policy(checkpoint_dir, step, mesh=mesh, **load_kwargs)
    return ControllerService.from_policy(policy, buckets=buckets,
                                         max_slots=max_slots)

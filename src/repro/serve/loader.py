"""Restore a trained multitask policy from a fleet checkpoint — params only.

`FleetRunner` checkpoints its full durability tree
`{"params", "opt", "broker"}` (core/checkpoints.py layout: one .npy per
leaf + a manifest of keystr paths).  Serving needs none of the optimizer
moments or broker rings — on a big fleet they dwarf the policy — so the
loader reads the manifest, selects exactly the `['params']...` leaves,
and rebuilds the policy subtree against a template derived from the
checkpoint's own metadata:

  * scenario names come from `meta["scenarios"]` (written by every fleet
    checkpoint), each resolved through the env registry so the serving
    `MultiTaskConfig` carries the same `HeadSpec`s training used;
  * trunk hyperparameters come from `meta["d_embed"]`/
    `meta["n_shared_layers"]` when present, and are otherwise inferred
    from the manifest itself (layer count from the
    `['params']['shared']['actor'][i]` key lattice, width from the
    recorded weight shapes) — checkpoints written before the meta fields
    existed stay loadable;
  * every selected leaf is validated (shape + dtype) against the template
    before unflattening, so a config/checkpoint mismatch fails loudly
    instead of serving garbage.

The training mesh does not constrain the serving mesh: pass `mesh=` to
re-place the restored tree replicated on a *different* topology via
`core/elastic.reshard` (the preemption/restore path — a policy trained on
a 2-shard mesh serves from a single-device box and vice versa).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from ..core import checkpoints, elastic
from ..fleet import multitask

_PARAMS_PREFIX = "['params']"
_ACTOR_LAYER_RE = re.compile(
    r"^\['params'\]\['shared'\]\['actor'\]\[(\d+)\]\['w'\]$")


@dataclasses.dataclass(frozen=True)
class LoadedPolicy:
    """A restored, serve-ready policy: the params tree + the static config
    that routes scenario names to heads, plus checkpoint provenance."""

    params: dict
    mcfg: multitask.MultiTaskConfig
    step: int
    meta: dict

    @property
    def scenarios(self) -> tuple[str, ...]:
        return self.mcfg.names


def _infer_trunk_shape(manifest: dict) -> tuple[int, int]:
    """(d_embed, n_shared_layers) read off the manifest key lattice —
    the fallback for checkpoints whose meta predates the explicit fields."""
    layers: dict[int, list[int]] = {}
    for key, shape in zip(manifest["keys"], manifest["shapes"]):
        m = _ACTOR_LAYER_RE.match(key)
        if m:
            layers[int(m.group(1))] = shape
    if not layers:
        raise checkpoints.IntegrityError(
            "checkpoint has no ['params']['shared']['actor'] leaves — not a "
            "fleet (multitask) checkpoint")
    n_layers = max(layers) + 1
    d_embed = layers[0][-1]
    return int(d_embed), int(n_layers)


def _mcfg_from_manifest(manifest: dict, env_overrides: dict | None
                        ) -> multitask.MultiTaskConfig:
    from .. import envs

    meta = manifest.get("meta", {})
    names = meta.get("scenarios")
    if not names:
        raise checkpoints.IntegrityError(
            "checkpoint meta carries no 'scenarios' list — cannot rebuild "
            "the multitask heads (was this written by FleetRunner?)")
    d_embed, n_layers = _infer_trunk_shape(manifest)
    # the explicit meta fields (written since the serve subsystem landed)
    # must agree with the arrays actually on disk
    for field, inferred in (("d_embed", d_embed), ("n_shared_layers", n_layers)):
        declared = meta.get(field)
        if declared is not None and int(declared) != inferred:
            raise checkpoints.IntegrityError(
                f"checkpoint meta declares {field}={declared} but the stored "
                f"arrays imply {inferred}")
    overrides = env_overrides or {}
    named = [(n, envs.make(n, **overrides.get(n, {}))) for n in names]
    return multitask.MultiTaskConfig.from_envs(
        named, d_embed=d_embed, n_shared_layers=n_layers)


def load_policy(checkpoint_dir: str, step: int | None = None, *,
                mesh: Mesh | None = None, verify: bool = True,
                env_overrides: dict[str, dict] | None = None) -> LoadedPolicy:
    """Restore the newest (or a specific) fleet checkpoint for serving.

    Returns a `LoadedPolicy` whose `params` hold ONLY the policy subtree,
    placed replicated on `mesh` when given (any topology — see module
    docstring), as committed device arrays otherwise.  `env_overrides`
    maps scenario name -> registry keyword overrides, for serving a head
    against a re-parameterized env (the specs must stay identical).
    """
    if step is None:
        step = checkpoints.latest_step(checkpoint_dir)
        if step is None:
            raise FileNotFoundError(
                f"no complete checkpoint under {checkpoint_dir!r}")
    arrays, manifest = checkpoints.restore_arrays(checkpoint_dir, step,
                                                  verify=verify)
    mcfg = _mcfg_from_manifest(manifest, env_overrides)

    selected = [a for key, a in zip(manifest["keys"], arrays)
                if key.startswith(_PARAMS_PREFIX)]
    template = jax.eval_shape(
        lambda k: multitask.init(k, mcfg), jax.random.PRNGKey(0))
    tdef = jax.tree.structure(template)
    leaves = jax.tree.leaves(template)
    if len(leaves) != len(selected):
        raise checkpoints.IntegrityError(
            f"policy template has {len(leaves)} leaves, checkpoint stores "
            f"{len(selected)} under {_PARAMS_PREFIX}")
    for i, (want, got) in enumerate(zip(leaves, selected)):
        if tuple(want.shape) != tuple(got.shape) or want.dtype != got.dtype:
            raise checkpoints.IntegrityError(
                f"params leaf {i}: checkpoint {got.shape}/{got.dtype} != "
                f"template {want.shape}/{want.dtype}")
    params = jax.tree.unflatten(tdef, [np.asarray(a) for a in selected])
    if mesh is not None:
        params = elastic.reshard(params, mesh, PartitionSpec())
    else:
        params = jax.tree.map(jax.numpy.asarray, params)
    return LoadedPolicy(params=params, mcfg=mcfg, step=int(step),
                        meta=dict(manifest.get("meta", {})))

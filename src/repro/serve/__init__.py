"""Trained-controller serving: batched low-latency inference for fleet
checkpoints.

Training (`fleet/pipeline.py`) produces one multitask parameter tree —
shared trunk + per-scenario adapters/heads — and checkpoints it together
with the optimizer and broker state.  This package is the other half of
the paper's HPC story: any solver, anywhere, calls the trained
eddy-viscosity controllers as a service (SmartFlow's solver-agnostic
deployment framing).  Three layers:

  * `loader`  — restore ONLY the policy subtree from a fleet checkpoint
                (the optimizer moments and broker rings stay on disk) and
                rebuild the `MultiTaskConfig` from the checkpoint's own
                metadata, optionally re-placing the tree on a serving mesh
                that need not match the training mesh
                (`core/elastic.reshard` — the preemption/restore path);
  * `batcher` — pad heterogeneous per-scenario request queues to a fixed
                ladder of compiled batch buckets, preserving per-request
                order, with slot recycling for streaming callers;
  * `service` — route requests by registered scenario name through ONE
                jitted `serve_step` per (scenario, batch-bucket):
                deterministic greedy actions (`multitask.actor_mean`, the
                exact training-time evaluation path — served actions are
                bit-identical to `Orchestrator.evaluate`'s at fp32) with a
                donated on-device request-counter buffer.

`benchmarks/perf_serve.py` publishes the p50/p99 latency + throughput
ladder (`perf_serve.json`), compile-certified under the trace auditor,
and the `serve_step` entry point is registered in
`analysis/entrypoints.py` so repro-lint gates its donation/f64
invariants.
"""
from .batcher import (DEFAULT_BUCKETS, PendingBatch, RequestBatcher,
                      bucket_for)
from .loader import LoadedPolicy, load_policy
from .service import ControllerService, ServeResult, load_service

__all__ = [
    "DEFAULT_BUCKETS",
    "PendingBatch",
    "RequestBatcher",
    "bucket_for",
    "LoadedPolicy",
    "load_policy",
    "ControllerService",
    "ServeResult",
    "load_service",
]

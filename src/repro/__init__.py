"""JAX/Pallas reproduction of 'Deep Reinforcement Learning for
Computational Fluid Dynamics on HPC Systems' (Relexi), grown toward a
production-scale system.

A regular package (not a namespace package) so tools that walk the source
tree — `pytest --doctest-modules src/repro/envs` in the docs CI job, most
prominently — resolve `repro.*` module names and relative imports.
"""

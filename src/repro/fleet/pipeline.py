"""Pipelined heterogeneous-fleet training: the multi-scenario front-end.

`FleetOrchestrator` lays one mesh's environment budget out as per-scenario
sub-fleets (one core `Orchestrator` each, so banks, sharding, and the
jitted rollout programs are exactly the single-scenario machinery), and
`FleetRunner` drives them through a double-buffered rollout/update pipeline
brokered by `fleet/broker.py`.

With `single_program=True` (the default) the whole iteration is ONE
compiled program (`fleet/superbatch.py`): the per-scenario sub-fleets are
laid out as a scenario-major super-batch, `shard_map`-ped over the mesh's
`data` axis, and update k + rollout k+1 + the broker pushes all live in a
single XLA dispatch — cross-scenario stragglers are load-balanced inside
the program instead of hidden by the dispatch queue.  With
`single_program=False` the pre-PR-8 per-scenario dispatch path runs
instead (kept as the measured baseline for
`benchmarks/fleet_scaling.py: single_program_vs_dispatch_speedup`, and as
the reference side of the bit-identity conformance pin):

    iteration k (pipelined):
        traj_k        <- broker slot k % 2        (rolled last iteration)
        dispatch  update_k(params_k, traj_k)      -> params_{k+1}
        dispatch  rollout_{k+1}(params_k)         (all sub-fleets)
        dispatch  push traj_{k+1} -> slot (k+1)%2 (donated, in-place)
        dispatch  push stats_k -> metrics ring    (no device_get)

    Nothing in the loop blocks on the device: the host runs ahead
    enqueueing work, rollout k+1 and update k overlap (in ONE program by
    default, in the XLA queue on the dispatch path — they share only
    params_k, which both read), and metric traffic stays device-resident
    until a checkpoint boundary drains it.  The price is the standard
    one-iteration policy lag (traj_k was rolled with params_{k-1});
    `pipelined=False` recovers the paper's strictly synchronous semantics,
    and `benchmarks/fleet_scaling.py` measures the overlap win of the
    default.

Determinism contract (the multi-scenario extension of core/runner.py's):
iteration k of scenario i is a pure function of (seed, i, k, params) —
rollout keys are `fold_in(fold_in(seed_key, i), k)`, bank seeds are
`scheduler.scenario_seed(seed, i)`, and the checkpoint state tree carries
params + optimizer + THE BROKER (the in-flight trajectory included), so a
restored pipelined run replays bit-identically (pinned by
tests/test_fleet.py's mixed-fleet replay test).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from .. import optim
from ..core import ppo as ppo_lib
from ..core.orchestrator import FleetConfig, Orchestrator
from ..core.runner import RunnerBase, RunnerConfig
from . import broker as broker_lib
from . import multitask, scheduler as sched_lib
from . import superbatch as superbatch_lib
from .scheduler import FleetSchedule


@dataclasses.dataclass(frozen=True)
class FleetRunnerConfig(RunnerConfig):
    """RunnerConfig + the fleet-specific knobs."""

    checkpoint_dir: str = "checkpoints/fleet"
    pipelined: bool = True        # False -> paper-synchronous semantics
    single_program: bool = True   # ONE compiled program per iteration
                                  # (False -> per-scenario dispatch path)
    bank_size: int = 17           # per-scenario initial-state bank
    traj_capacity: int = 2        # 2 == double buffering (pipeline minimum)
    metrics_capacity: int = 512   # device-resident metric history per scenario
    d_embed: int = 32             # shared-trunk width (multitask policy)
    n_shared_layers: int = 2


def _host_record(rec: dict) -> dict:
    """Drained metric record -> JSON-ready host values.

    Scalar metrics become Python floats; vector-valued metrics arrive from
    `broker.drain_host` as nested lists and pass through unchanged (a
    non-scalar leaf used to reach the former unconditional `float(v)` as a
    numpy array and crash the training loop at drain time).
    """
    return {key: v if isinstance(v, list) else float(v)
            for key, v in rec.items()}


class FleetOrchestrator:
    """Per-scenario sub-fleet orchestrators + the shared multitask policy."""

    def __init__(self, schedule: FleetSchedule, *, mesh=None, seed: int = 0,
                 bank_size: int = 17, d_embed: int = 32,
                 n_shared_layers: int = 2):
        self.schedule = schedule
        self.mcfg = multitask.MultiTaskConfig.from_envs(
            [(m.name, m.env) for m in schedule.members],
            d_embed=d_embed, n_shared_layers=n_shared_layers)
        # One core Orchestrator per scenario: same banks, sharding, and
        # jitted rollout programs as single-scenario training, with the
        # scenario's multitask head plugged in as the policy bundle.
        self.orchs = {
            m.name: Orchestrator(
                m.env, FleetConfig(n_envs=m.n_envs, bank_size=bank_size),
                mesh=mesh, seed=sched_lib.scenario_seed(seed, i),
                policy=multitask.policy_fns(self.mcfg, m.name))
            for i, m in enumerate(schedule.members)
        }

    @property
    def names(self) -> tuple[str, ...]:
        return self.schedule.names

    def sample_all(self, params: dict, keys: dict[str, jax.Array]
                   ) -> dict[str, ppo_lib.Trajectory]:
        """Dispatch every sub-fleet's rollout (one jitted program each);
        returns without blocking — the trajectories are in-flight arrays."""
        return {name: self.orchs[name].sample_fleet(params, keys[name])
                for name in self.names}

    def evaluate_all(self, params: dict) -> dict[str, float]:
        """Deterministic held-out-state episode per scenario (blocks)."""
        return {name: float(self.orchs[name].evaluate(params))
                for name in self.names}


class FleetRunner(RunnerBase):
    """Heterogeneous-fleet training with the Runner durability contract."""

    def __init__(self, schedule: FleetSchedule,
                 ppo_cfg: ppo_lib.PPOConfig | None = None,
                 run_cfg: FleetRunnerConfig | None = None, *, mesh=None):
        super().__init__(run_cfg or FleetRunnerConfig())
        cfg = self.run_cfg
        self.ppo_cfg = ppo_cfg or ppo_lib.PPOConfig()
        self.schedule = schedule
        self.forch = FleetOrchestrator(
            schedule, mesh=mesh, seed=cfg.seed, bank_size=cfg.bank_size,
            d_embed=cfg.d_embed, n_shared_layers=cfg.n_shared_layers)
        self.mcfg = self.forch.mcfg
        self.weights = {m.name: m.weight for m in schedule.members}

        key = jax.random.PRNGKey(cfg.seed)
        self.seed_key, init_key = jax.random.split(key)
        self.params = multitask.init(init_key, self.mcfg)
        self.opt_state = optim.adam_init(self.params)

        # donate the optimizer state: it aliases its own output, so both
        # moment generations never live at once (params are NOT donated —
        # the in-flight overlapped rollout still reads them)
        self._update = jax.jit(self._update_impl, donate_argnums=(1,))

        # broker rings sized from the abstract trajectory/stats shapes
        # (eval_shape: no rollout or update actually runs here)
        traj_templates = {
            name: jax.eval_shape(self.forch.orchs[name].sample_fleet,
                                 self.params, jax.random.PRNGKey(0))
            for name in self.forch.names}
        stats_template = jax.eval_shape(
            self._update_impl, self.params, self.opt_state, traj_templates,
            jnp.zeros((), jnp.int32))[2]
        self.broker = broker_lib.broker_init(
            traj_templates, traj_capacity=cfg.traj_capacity,
            metric_templates={"fleet": stats_template},
            metrics_capacity=cfg.metrics_capacity)

        # the single fleet program (the default iteration path): update k,
        # the shard_map-ped super-batch rollout k+1, and the broker pushes
        # compiled into one XLA dispatch (fleet/superbatch.py)
        self.program = (superbatch_lib.FleetProgram(
            self.forch, self.weights, self.ppo_cfg, mesh=mesh)
            if cfg.single_program else None)

    # --- jitted joint update --------------------------------------------------
    def _update_impl(self, params, opt_state, trajs, k):
        # in-graph non-finite guard rides inside the program: the pipelined
        # loop never syncs to inspect stats (core/runner.py makes the same
        # call on the host instead); shared with the single fleet program
        return superbatch_lib.guarded_fleet_update(
            params, opt_state, self.ppo_cfg, self.mcfg, trajs, self.weights,
            k)

    # --- checkpoint hooks -----------------------------------------------------
    def _state_tree(self) -> dict:
        return {"params": self.params, "opt": self.opt_state,
                "broker": self.broker}

    def _load_state(self, tree: dict, manifest: dict) -> None:
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.broker = tree["broker"]
        self.iteration = int(manifest["meta"]["iteration"])

    def _checkpoint_meta(self) -> dict:
        # scenarios + trunk hyperparameters make the checkpoint
        # self-describing for the serving loader (repro.serve.load_policy
        # rebuilds the MultiTaskConfig from this meta alone; older
        # checkpoints without the trunk fields fall back to shape inference)
        return {**super()._checkpoint_meta(),
                "scenarios": list(self.forch.names),
                "n_envs": {m.name: m.n_envs for m in self.schedule.members},
                "pipelined": self.run_cfg.pipelined,
                "d_embed": self.run_cfg.d_embed,
                "n_shared_layers": self.run_cfg.n_shared_layers}

    # --- key bookkeeping ------------------------------------------------------
    def _keys(self, k: int) -> dict[str, jax.Array]:
        return {name: sched_lib.rollout_key(self.seed_key, i, k)
                for i, name in enumerate(self.forch.names)}

    # --- iteration bodies -----------------------------------------------------
    def _push_all(self, trajs: dict, stats) -> None:
        for name, traj in trajs.items():
            self.broker = self.broker._replace(traj={
                **self.broker.traj,
                name: broker_lib.push_donated(self.broker.traj[name], traj)})
        if stats is not None:
            self.broker = self.broker._replace(metrics={
                **self.broker.metrics,
                "fleet": broker_lib.push_donated(self.broker.metrics["fleet"],
                                                 stats)})

    def run_iteration_pipelined(self, k: int) -> None:
        """Dispatch-only iteration: consume traj_k from the broker, overlap
        rollout k+1 with update k, park the results back in the broker.

        Default (`single_program`): ONE compiled program carries all of it
        — XLA schedules the dependency-free update-k / rollout-(k+1)
        subgraphs concurrently, and a straggling scenario inside the
        super-batch only delays its own rows, not a whole dispatch.

        Dispatch fallback: both programs read `params_k`; the update is
        ENQUEUED first so that a strictly in-order backend retires
        params_{k+1} without waiting on rollout k+1 — the next rollout is
        always the computation left in flight when the host runs ahead
        (steady-state double buffering).
        """
        if self.program is not None:
            self.params, self.opt_state, self.broker = self.program.step(
                self.params, self.opt_state, self.broker,
                jnp.asarray(k, jnp.int32), self._keys(k + 1))
            return
        params_k = self.params
        trajs_k = {name: broker_lib.latest_traj(self.broker, name)
                   for name in self.forch.names}
        self.params, self.opt_state, stats = self._update(
            params_k, self.opt_state, trajs_k, jnp.asarray(k, jnp.int32))
        next_trajs = self.forch.sample_all(params_k, self._keys(k + 1))
        self._push_all(next_trajs, stats)

    def run_iteration_sync(self, k: int) -> dict:
        """Paper-synchronous iteration: sample -> block -> update -> block,
        with the per-iteration host metrics readback core/runner.py does.
        The strict on-policy mode, and the benchmark baseline."""
        t0 = time.perf_counter()
        trajs = self.forch.sample_all(self.params, self._keys(k))
        trajs = jax.block_until_ready(trajs)
        t_sample = time.perf_counter() - t0
        t0 = time.perf_counter()
        self.params, self.opt_state, stats = self._update(
            self.params, self.opt_state, dict(trajs),
            jnp.asarray(k, jnp.int32))
        host_stats = jax.device_get(stats)  # blocks: the sync-mode contract
        t_update = time.perf_counter() - t0
        self._push_all(trajs, stats)
        return {"iteration": k, "t_sample_s": t_sample,
                "t_update_s": t_update,
                **{name: float(v) for name, v in host_stats.items()}}

    # --- training -------------------------------------------------------------
    def train(self, n_iterations: int | None = None, *,
              resume: bool = True) -> list[dict]:
        """Run until `n_iterations`; returns this call's per-iteration
        metric records (drained from the device ring at the end)."""
        cfg = self.run_cfg
        total = n_iterations or cfg.n_iterations
        if resume:
            self.restore()
        head_start = int(jax.device_get(self.broker.metrics["fleet"].head))
        timings: list[dict] = []

        # pipeline prologue: the broker must hold traj_0 before update 0
        if cfg.pipelined and int(jax.device_get(
                self.broker.traj[self.forch.names[0]].head)) == 0:
            if self.program is not None:
                self.broker = self.program.prologue(
                    self.params, self.broker, self._keys(0))
            else:
                self._push_all(
                    self.forch.sample_all(self.params, self._keys(0)), None)

        while self.iteration < total:
            k = self.iteration
            if cfg.pipelined:
                self.run_iteration_pipelined(k)
            else:
                timings.append(self.run_iteration_sync(k))
            self.iteration = k + 1
            if (k + 1) % cfg.eval_every == 0:
                evals = self.forch.evaluate_all(self.params)  # blocks (cadenced)
                self._log({"iteration": k,
                           **{f"{n}/eval_return_norm": v
                              for n, v in evals.items()}})
            if (k + 1) % cfg.checkpoint_every == 0:
                self.save_checkpoint()
        self.save_checkpoint(block=True)
        self.join_pending_checkpoint()

        # drain this call's device-resident metrics into the jsonl stream
        head_end = int(jax.device_get(self.broker.metrics["fleet"].head))
        n_new = head_end - head_start
        drained = broker_lib.drain_host(self.broker)["fleet"]
        # the ring only holds metrics_capacity records: a longer call loses
        # the oldest ones — say so instead of silently under-reporting
        records = drained[-n_new:] if n_new > 0 else []
        if n_new > len(records):
            self._log({"dropped_metric_records": n_new - len(records),
                       "metrics_capacity": cfg.metrics_capacity})
        timing_by_iter = {t["iteration"]: t for t in timings}
        history = []
        for rec in records:
            rec = _host_record(rec)
            for name in self.forch.names:
                n_steps = self.forch.orchs[name].env.n_actions
                rec[f"{name}/return_norm"] = (
                    rec[f"{name}/mean_return"] / n_steps)
            # sync-mode host timings, matched by iteration (records may be
            # a ring-bounded suffix, so positional pairing would misalign)
            rec.update(timing_by_iter.get(int(rec["iteration"]), {}))
            self._log(rec)
            history.append(rec)
        return history


def make_fleet_runner(names, total_envs: int = 6, *,
                      ppo_cfg: ppo_lib.PPOConfig | None = None,
                      run_cfg: FleetRunnerConfig | None = None,
                      mesh=None, costs: dict[str, float] | None = None,
                      **schedule_kwargs) -> FleetRunner:
    """Convenience: registry names -> schedule -> FleetRunner."""
    from .. import envs

    schedule = sched_lib.build_schedule(
        [(n, envs.make(n)) for n in names], total_envs, costs=costs,
        **schedule_kwargs)
    return FleetRunner(schedule, ppo_cfg=ppo_cfg, run_cfg=run_cfg, mesh=mesh)

"""Device-resident experience broker — the SmartSim/KeyDB analog on-device.

The paper stages every state/action/trajectory exchange through an
in-memory KeyDB database: FLEXI instances PUT trajectories, the TF-Agents
driver GETs them, and the broker decouples the producers from the consumer.
This module is that broker taken to its endpoint on an accelerator mesh:
per-scenario ring buffers of whole `Trajectory` pytrees living in device
memory, written and read by jitted programs.  Three things fall out:

  * decoupling — rollout (producer) and PPO update (consumer) communicate
    only through ring slots, so `fleet/pipeline.py` can dispatch the
    iteration-(k+1) rollout while the iteration-k update still runs
    (capacity 2 == classic double buffering; the writer and reader slots
    never alias),
  * off-critical-path metrics — per-iteration scalar stats are pushed into
    a small metrics ring instead of `device_get` every iteration; the host
    drains the ring at checkpoint boundaries (`drain_host`), so the hot
    loop never blocks on a host round-trip,
  * durability — a ring is a plain pytree of arrays plus an int32 write
    head, so the whole broker drops into the checkpoint state tree and the
    in-flight trajectory survives restart bit-exactly (the fleet's
    deterministic-replay contract, pinned by tests/test_fleet.py).

Everything here is functional: `push` returns a NEW ring (donate the old
one at the jit boundary for in-place updates — see `make_push`).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class RingBuffer(NamedTuple):
    """A fixed-capacity ring of pytree items, device-resident.

    `data` holds the items stacked on a leading slot axis of length
    `capacity`; `head` counts TOTAL pushes (monotonic int32) — the write
    slot is `head % capacity`, and `head` doubles as the logical clock that
    makes resume deterministic.
    """

    data: Any          # pytree; every leaf (capacity, *item_shape)
    head: jax.Array    # () int32, number of pushes so far


def capacity(ring: RingBuffer) -> int:
    return jax.tree.leaves(ring.data)[0].shape[0]


def size(ring: RingBuffer) -> jax.Array:
    """Number of valid items currently held (<= capacity)."""
    return jnp.minimum(ring.head, capacity(ring))


def ring_init(template: Any, cap: int) -> RingBuffer:
    """An empty ring whose slots have the shapes/dtypes of `template`
    (an example item — e.g. one `Trajectory` from `jax.eval_shape`)."""
    data = jax.tree.map(
        lambda x: jnp.zeros((cap,) + tuple(x.shape), x.dtype), template)
    return RingBuffer(data=data, head=jnp.zeros((), jnp.int32))


def push(ring: RingBuffer, item: Any) -> RingBuffer:
    """Write `item` at the head slot; returns the advanced ring."""
    cap = capacity(ring)
    slot = ring.head % cap
    data = jax.tree.map(
        lambda buf, x: jax.lax.dynamic_update_index_in_dim(
            buf, x.astype(buf.dtype), slot, 0),
        ring.data, item)
    return RingBuffer(data=data, head=ring.head + 1)


def peek(ring: RingBuffer, age: int = 0) -> Any:
    """The item pushed `age` slots ago (0 = newest).  Reading an empty ring
    returns the zero template (callers gate on `size`)."""
    cap = capacity(ring)
    slot = (ring.head - 1 - age) % cap
    return jax.tree.map(
        lambda buf: jax.lax.dynamic_index_in_dim(buf, slot, 0,
                                                 keepdims=False),
        ring.data)


# Jitted, donating push: the old ring's buffers are donated, so XLA updates
# the slot in place instead of copying `capacity` trajectories per push (one
# compiled instance per ring shape, cached by jit as usual).
push_donated = jax.jit(push, donate_argnums=(0,))


class Broker(NamedTuple):
    """Per-scenario trajectory rings + per-scenario metrics rings.

    A plain pytree (dict values are RingBuffers) — it drops into the
    checkpoint state tree unchanged and `jax.device_get` round-trips it.
    """

    traj: dict[str, RingBuffer]
    metrics: dict[str, RingBuffer]


def broker_init(traj_templates: dict[str, Any], *, traj_capacity: int = 2,
                metric_templates: dict[str, Any] | None = None,
                metrics_capacity: int = 256) -> Broker:
    """Build the broker from per-scenario example items.

    traj_capacity=2 is the double-buffering minimum the pipeline needs;
    larger values keep a short experience history (e.g. for off-policy
    diagnostics) at the price of device memory.
    """
    traj = {name: ring_init(t, traj_capacity)
            for name, t in traj_templates.items()}
    metrics = {name: ring_init(t, metrics_capacity)
               for name, t in (metric_templates or {}).items()}
    return Broker(traj=traj, metrics=metrics)


def push_traj(broker: Broker, name: str, item: Any) -> Broker:
    return broker._replace(traj={**broker.traj,
                                 name: push(broker.traj[name], item)})


def push_metrics(broker: Broker, name: str, item: Any) -> Broker:
    return broker._replace(metrics={**broker.metrics,
                                    name: push(broker.metrics[name], item)})


def latest_traj(broker: Broker, name: str) -> Any:
    return peek(broker.traj[name])


def drain_host(broker: Broker) -> dict[str, list[dict]]:
    """Host-side read of every metrics ring, oldest first — the ONLY place
    the broker touches the host.  Called at checkpoint boundaries / end of
    training, never inside the iteration hot loop.

    Every drained leaf is a plain host value: Python floats/ints for
    scalar metrics, nested Python lists (`tolist()`) for vector-valued
    ones — so records are JSON-serializable as drained and consumers never
    see stray numpy arrays (a vector leaf used to come back as an ndarray,
    which crashed the runner's `float(v)` record conversion downstream).
    """
    out: dict[str, list[dict]] = {}
    for name, ring in broker.metrics.items():
        n = int(jax.device_get(size(ring)))
        head = int(jax.device_get(ring.head))
        cap = capacity(ring)
        data = jax.device_get(ring.data)
        records = []
        for i in range(n):
            slot = (head - n + i) % cap
            records.append(jax.tree.map(lambda buf: buf[slot].item()
                                        if buf[slot].ndim == 0
                                        else buf[slot].tolist(),
                                        data))
        out[name] = records
    return out

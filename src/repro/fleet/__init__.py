"""Multi-scenario fleet subsystem: heterogeneous sub-fleets on one mesh.

    from repro import envs, fleet

    runner = fleet.make_fleet_runner(
        ("hit_les_reduced", "channel_wm_reduced", "burgers_reduced"),
        total_envs=6)
    history = runner.train(5)

Four pieces (see docs/multi_scenario_training.md for how they compose):

  broker      device-resident per-scenario trajectory/metric ring buffers
              (the SmartSim/KeyDB experience broker taken fully on-device)
  scheduler   cost-weighted partition of the mesh batch axis into
              per-scenario sub-fleets + the fleet's PRNG/bank bookkeeping
  multitask   shared-trunk policy with per-scenario adapters and heads,
              built from each env's declared ObsSpec/ActionSpec
  pipeline    double-buffered rollout/update overlap (FleetRunner), with
              the core Runner's checkpoint/restore durability contract
  superbatch  the whole fleet's iteration as ONE compiled program: the
              scenario-major super-batch rollout shard_map-ped over the
              mesh `data` axis + the joint update + the broker pushes
"""
from . import broker, multitask, pipeline, scheduler, superbatch
from .multitask import MultiTaskConfig, fleet_update
from .pipeline import FleetOrchestrator, FleetRunner, FleetRunnerConfig, \
    make_fleet_runner
from .scheduler import FleetSchedule, SubFleet, build_schedule
from .superbatch import FleetProgram

__all__ = [
    "FleetOrchestrator",
    "FleetProgram",
    "FleetRunner",
    "FleetRunnerConfig",
    "FleetSchedule",
    "MultiTaskConfig",
    "SubFleet",
    "broker",
    "build_schedule",
    "fleet_update",
    "make_fleet_runner",
    "multitask",
    "pipeline",
    "scheduler",
    "superbatch",
]

"""Multi-scenario policy: shared trunk, per-scenario adapters and heads.

One parameter tree serves every scenario in a heterogeneous fleet.  The
scenarios disagree on everything the single-scenario Conv policy hard-wires
— spatial rank (3-D HIT vs 1-D Burgers), per-element node count, channel
count, action bounds — so the sharing happens in a rank-free embedding
space instead:

    obs (..., E, *spatial, C)
      -> declared per-channel gains (ObsSpec.channel_specs — PR 4's
         declarations are what make this constructible without touching
         any solver)
      -> flatten per-element nodes to F = prod(spatial) * C features
      -> per-scenario ADAPTER: dense F -> d_embed            (scenario)
      -> shared TRUNK: n_shared_layers x [dense d -> d, ReLU] (shared)
      -> per-scenario HEAD: dense d -> 1                      (scenario)
    actor:  mean = low + (high - low) * sigmoid(head)  per element,
            per-scenario learnable log_std (TF-Agents continuous-PPO form,
            as in core/policy.py)
    critic: mean over elements of the per-element head scalar

Every per-scenario function is exposed as a `core.policy.PolicyFns` bundle
(`policy_fns(mcfg, name)`), so the UNCHANGED rollout scan and PPO loss in
`core/` drive it; `fleet_update` is the joint PPO step — one Adam update on
the whole tree from the cost-weighted sum of per-scenario losses, which is
what trains the shared trunk on all scenarios at once.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn, optim
from ..core import policy as policy_lib
from ..core import ppo as ppo_lib
from ..envs.base import Env


@dataclasses.dataclass(frozen=True)
class HeadSpec:
    """Static per-scenario head declaration, derived from the env specs."""

    name: str
    n_elements: int
    spatial: tuple[int, ...]
    channels: int
    gains: tuple[float, ...]
    act_low: float
    act_high: float

    @classmethod
    def from_env(cls, name: str, env: Env) -> "HeadSpec":
        obs, act = env.obs_spec, env.action_spec
        return cls(name=name, n_elements=obs.n_elements,
                   spatial=tuple(obs.spatial), channels=obs.channels,
                   gains=tuple(obs.channel_gains),
                   act_low=act.low, act_high=act.high)

    @property
    def in_features(self) -> int:
        """F: flattened per-element feature width."""
        return int(np.prod(self.spatial)) * self.channels


@dataclasses.dataclass(frozen=True)
class MultiTaskConfig:
    """Hashable static configuration (closed over by jit like PolicyConfig)."""

    heads: tuple[HeadSpec, ...]
    d_embed: int = 32
    n_shared_layers: int = 2
    log_std_init: float = -1.6

    def __post_init__(self):
        names = self.names
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate head names: {names}")

    @classmethod
    def from_envs(cls, named_envs, **kwargs) -> "MultiTaskConfig":
        """Build from [(name, env), ...] — each head from the env's specs."""
        return cls(heads=tuple(HeadSpec.from_env(n, e) for n, e in named_envs),
                   **kwargs)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(h.name for h in self.heads)

    def head(self, name: str) -> HeadSpec:
        for h in self.heads:
            if h.name == name:
                return h
        raise KeyError(f"unknown scenario head {name!r}; have {self.names}")


# --- parameters ---------------------------------------------------------------
def init(key: jax.Array, cfg: MultiTaskConfig) -> dict:
    k_shared, k_heads = jax.random.split(key)
    ka, kc = jax.random.split(k_shared)
    shared = {
        "actor": [nn.dense_init(k, cfg.d_embed, cfg.d_embed)
                  for k in jax.random.split(ka, cfg.n_shared_layers)],
        "critic": [nn.dense_init(k, cfg.d_embed, cfg.d_embed)
                   for k in jax.random.split(kc, cfg.n_shared_layers)],
    }
    heads = {}
    for h, kh in zip(cfg.heads, jax.random.split(k_heads, len(cfg.heads))):
        k1, k2, k3, k4 = jax.random.split(kh, 4)
        heads[h.name] = {
            "actor_in": nn.dense_init(k1, h.in_features, cfg.d_embed),
            "critic_in": nn.dense_init(k2, h.in_features, cfg.d_embed),
            "actor_out": nn.dense_init(k3, cfg.d_embed, 1),
            "critic_out": nn.dense_init(k4, cfg.d_embed, 1),
            "log_std": jnp.full((), cfg.log_std_init, jnp.float32),
        }
    return {"shared": shared, "heads": heads}


def param_count(params: dict) -> int:
    return nn.param_count(params)


# --- forward ------------------------------------------------------------------
def _features(head: HeadSpec, obs: jax.Array) -> jax.Array:
    """(..., E, *spatial, C) -> (..., E, F) with declared gains applied."""
    x = obs
    if any(g != 1.0 for g in head.gains):
        x = x * jnp.asarray(head.gains, x.dtype)
    lead = x.shape[: x.ndim - (len(head.spatial) + 1)]
    return x.reshape(lead + (head.in_features,))


def _head_scalar(shared: list, adapter: dict, out: dict,
                 head: HeadSpec, obs: jax.Array) -> jax.Array:
    """Adapter -> shared trunk -> head: per-element scalar (..., E)."""
    x = jax.nn.relu(nn.dense(adapter, _features(head, obs)))
    for layer in shared:
        x = jax.nn.relu(nn.dense(layer, x))
    return nn.dense(out, x)[..., 0]


def actor_mean(params: dict, cfg: MultiTaskConfig, name: str,
               obs: jax.Array) -> jax.Array:
    h = cfg.head(name)
    p = params["heads"][name]
    logits = _head_scalar(params["shared"]["actor"], p["actor_in"],
                          p["actor_out"], h, obs)
    return h.act_low + (h.act_high - h.act_low) * jax.nn.sigmoid(logits)


def value(params: dict, cfg: MultiTaskConfig, name: str,
          obs: jax.Array) -> jax.Array:
    h = cfg.head(name)
    p = params["heads"][name]
    per_elem = _head_scalar(params["shared"]["critic"], p["critic_in"],
                            p["critic_out"], h, obs)
    return jnp.mean(per_elem, axis=-1)


def distribution(params: dict, cfg: MultiTaskConfig, name: str,
                 obs: jax.Array) -> tuple[jax.Array, jax.Array]:
    mean = actor_mean(params, cfg, name, obs)
    std = jnp.exp(params["heads"][name]["log_std"]).astype(mean.dtype)
    return mean, jnp.broadcast_to(std, mean.shape)


def sample_action(key: jax.Array, params: dict, cfg: MultiTaskConfig,
                  name: str, obs: jax.Array) -> tuple[jax.Array, jax.Array]:
    mean, std = distribution(params, cfg, name, obs)
    noise = jax.random.normal(key, mean.shape, mean.dtype)
    action = mean + std * noise
    return action, policy_lib.log_prob(mean, std, action)


# --- PolicyFns bundle (what core/rollout + core/ppo consume) ------------------
def policy_fns(cfg: MultiTaskConfig, name: str) -> policy_lib.PolicyFns:
    """The scenario-`name` head as the standard policy callable bundle."""
    cfg.head(name)  # fail fast on unknown scenarios
    return policy_lib.PolicyFns(
        sample=partial(_sample_h, cfg, name),
        mean=partial(_mean_h, cfg, name),
        dist=partial(_dist_h, cfg, name),
        value=partial(_value_h, cfg, name),
    )


def _sample_h(cfg, name, key, params, obs):
    return sample_action(key, params, cfg, name, obs)


def _mean_h(cfg, name, params, obs):
    return actor_mean(params, cfg, name, obs)


def _dist_h(cfg, name, params, obs):
    return distribution(params, cfg, name, obs)


def _value_h(cfg, name, params, obs):
    return value(params, cfg, name, obs)


# --- joint PPO update ---------------------------------------------------------
def fleet_update(
    params: dict,
    opt_state,
    cfg: ppo_lib.PPOConfig,
    mcfg: MultiTaskConfig,
    trajs: dict[str, ppo_lib.Trajectory],
    weights: dict[str, float],
) -> tuple[dict, object, dict]:
    """One joint PPO update over every scenario's trajectory batch.

    GAE + flattening + advantage normalization run PER SCENARIO (each
    scenario's reward scale normalizes against itself), the clipped losses
    combine as  sum_s w_s * L_s  with w_s the scheduler's env-share weights
    (so the joint loss is an unweighted per-environment mean across the
    fleet), and `n_epochs` full-batch Adam steps train adapters, heads, and
    the shared trunk together.  Iteration order over scenarios is the
    declared head order — part of the determinism contract.
    """
    names = [n for n in mcfg.names if n in trajs]
    flat: dict[str, tuple] = {}
    for name in names:
        traj = trajs[name]
        adv, ret = ppo_lib.gae(traj, cfg.gamma, cfg.lam)
        flat[name] = ppo_lib.flatten_batch(
            traj, adv, ret, normalize=cfg.normalize_advantages)

    def loss_fn(params):
        total = 0.0
        stats: dict[str, jax.Array] = {}
        for name in names:
            loss_s, st = ppo_lib.ppo_loss(
                params, cfg, None, *flat[name],
                policy=policy_fns(mcfg, name))
            total = total + weights[name] * loss_s
            for k, v in st.items():
                stats[f"{name}/{k}"] = v
        stats["loss"] = total
        return total, stats

    def epoch(carry, _):
        params, opt_state = carry
        (_, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = optim.adam_update(cfg.adam, params, grads,
                                              opt_state)
        stats["grad_norm"] = optim.global_norm(grads)
        return (params, opt_state), stats

    (params, opt_state), stats_seq = jax.lax.scan(
        epoch, (params, opt_state), None, length=cfg.n_epochs)
    stats = jax.tree.map(lambda s: s[-1], stats_seq)
    for name in names:
        stats[f"{name}/mean_return"] = jnp.mean(
            jnp.sum(trajs[name].rewards, axis=0))
    return params, opt_state, stats

"""Sub-fleet scheduler: partition the mesh batch axis across scenarios.

The paper sizes ONE homogeneous fleet (n_envs identical FLEXI instances).
A heterogeneous fleet must decide how many environments each scenario gets:
this module partitions a total environment budget into per-scenario
sub-fleets weighted by the INVERSE of each scenario's per-environment step
cost, so every sub-fleet costs roughly the same device time per iteration
and no scenario serializes the others (the fleet analog of the paper's
"ranks per FLEXI instance" sizing question).

Step costs come from, in priority order:

  1. explicit `costs` overrides,
  2. the AOT dry-run artifacts (`launch/dryrun.py run_relexi_cell /
     run_channel_cell` write `flops_per_env` — the measured XLA cost of one
     fleet MDP step), matched by the EXACT scenario the cell measured and
     used only when every non-overridden member has one (measured FLOPs
     and the static proxy are different units; mixing them in one
     partition would skew the weights arbitrarily),
  3. a static FLOP proxy: state DOF x solver substeps per RL step, read
     off the env's config — exact enough for sizing (both real costs scale
     with exactly those two factors).

The scheduler also owns the fleet's determinism bookkeeping: per-scenario
bank seeds (`scenario_seed`) and per-(scenario, iteration) rollout keys
(`rollout_key`), both pure functions of (base seed, scenario index) so a
restored run replays bit-identically regardless of scenario count or order.

Contract change (PR 8): `scenario_seed` derives bank seeds through
`jax.random.fold_in` instead of the former additive prime stride
`base_seed + 7919*(index+1)`, whose lattice collided across runs —
`(seed=s, index=i+1)` and `(seed=s+7919, index=i)` produced IDENTICAL
initial-state banks.  fold_in hashes (seed, index) jointly, so distinct
(seed, index) pairs give independent banks.  Bank contents therefore differ
from pre-PR-8 checkpoints; the (seed, index) -> bank mapping remains a pure
function and replays bit-identically within a run lineage.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from ..envs.base import Env
from ..launch import DRYRUN_ARTIFACT_DIR

# Scenarios whose dry-run fleet cell is identified by the record's `arch`
# tag alone (run_relexi_cell predates the `variant` field); every other
# cell names its exact scenario in `variant` (run_channel_cell).
_ARCH_EXACT = {
    "hit_les_24dof": "relexi-hit24",
    "hit_les_32dof": "relexi-hit32",
}


@dataclasses.dataclass(frozen=True)
class SubFleet:
    """One scenario's slice of the fleet: its env, environment count, loss
    weight (its share of the env budget, so the joint PPO loss stays an
    unweighted per-environment mean), and the per-env step cost that sized
    it."""

    name: str
    env: Env
    n_envs: int
    weight: float
    cost: float


@dataclasses.dataclass(frozen=True)
class FleetSchedule:
    """The full partition, ordered; scenario index = position (stable, part
    of the determinism contract — reordering scenarios is a new run)."""

    members: tuple[SubFleet, ...]

    def __post_init__(self):
        names = [m.name for m in self.members]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate scenario names: {names}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(m.name for m in self.members)

    @property
    def total_envs(self) -> int:
        return sum(m.n_envs for m in self.members)

    def member(self, name: str) -> SubFleet:
        for m in self.members:
            if m.name == name:
                return m
        raise KeyError(name)

    def index(self, name: str) -> int:
        return self.names.index(name)


# --- step-cost estimation ----------------------------------------------------
def static_step_cost(env: Env) -> float:
    """FLOP proxy for one env's RL step: state DOF x solver substeps.

    Generic over the Env protocol — the state shape comes from
    `eval_shape(initial_state_bank)` (no allocation), substeps from the
    config when it declares them (all DGSEM scenarios do).
    """
    bank = jax.eval_shape(lambda k: env.initial_state_bank(k, 1),
                          jax.random.PRNGKey(0))
    dof = float(np.prod(bank.shape[1:]))
    substeps = float(getattr(getattr(env, "cfg", None), "n_substeps", 1))
    return dof * substeps


def dryrun_step_cost(name: str, artifact_dir: str | None = None
                     ) -> float | None:
    """Per-env step cost measured by the AOT dry-run, if an artifact exists
    for EXACTLY this scenario.

    Reads the newest `*_fleet_*.json` whose record names the scenario
    (`variant == name`, or the legacy relexi `arch` tags) and carries
    `flops_per_env`; returns None otherwise — a cell measured at another
    scale must not price this one (the units are absolute XLA FLOPs).
    """
    directory = artifact_dir or DRYRUN_ARTIFACT_DIR
    paths = sorted(glob.glob(os.path.join(directory, "*_fleet_*.json")),
                   key=os.path.getmtime)
    for path in reversed(paths):  # newest usable artifact wins
        try:
            with open(path) as f:
                rec = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        # the arch-tag fallback must only fire for scenarios that HAVE a
        # legacy tag: for any other scenario `_ARCH_EXACT.get(name)` is
        # None, and a record without an `arch` field would match it
        # (None == None), pricing the scenario off an unrelated cell
        arch = _ARCH_EXACT.get(name)
        matches = (rec.get("variant") == name
                   or (arch is not None and arch == rec.get("arch")))
        if not (matches and rec.get("status") == "ok"):
            continue
        # Explicit None-check, NOT truthiness: a record that carries the
        # field with a measured 0.0 is a broken measurement and must fail
        # loudly instead of being silently discarded (a zero cost would
        # give the scenario an infinite share of the env budget).
        cost = rec.get("flops_per_env")
        if cost is None:
            continue  # record without a measurement: keep scanning
        cost = float(cost)
        if cost <= 0.0:
            raise ValueError(
                f"dry-run artifact {path} reports non-positive "
                f"flops_per_env={cost!r} for scenario {name!r}; "
                "re-run the dry-run cell")
        return cost
    return None


def _partition(weights: list[float], total: int, min_envs: int) -> list[int]:
    """Largest-remainder apportionment of `total` into len(weights) parts,
    each >= min_envs; deterministic (ties broken by position)."""
    if total < min_envs * len(weights):
        raise ValueError(f"total_envs={total} cannot give {len(weights)} "
                         f"scenarios >= {min_envs} envs each")
    s = sum(weights)
    raw = [total * w / s for w in weights]
    n = [max(min_envs, math.floor(r)) for r in raw]
    # hand out the remainder by descending fractional part (stable)
    order = sorted(range(len(raw)),
                   key=lambda i: (-(raw[i] - math.floor(raw[i])), i))
    i = 0
    while sum(n) < total:
        n[order[i % len(order)]] += 1
        i += 1
    # floors/minimums may have overshot: shave the largest members back
    while sum(n) > total:
        j = max(range(len(n)), key=lambda i: (n[i] > min_envs, n[i], -i))
        if n[j] <= min_envs:
            raise ValueError("cannot satisfy min_envs")  # unreachable: guarded
        n[j] -= 1
    return n


def build_schedule(named_envs, total_envs: int, *,
                   costs: dict[str, float] | None = None,
                   min_envs: int = 1,
                   artifact_dir: str | None = None,
                   use_artifacts: bool = True) -> FleetSchedule:
    """Partition `total_envs` across `named_envs` ([(name, env), ...]).

    Environments are apportioned inversely to per-env step cost so each
    sub-fleet's total per-iteration device time is balanced; `weight` is
    each member's env share (used by the joint PPO loss).
    """
    named_envs = list(named_envs)
    # Measured (artifact) costs are absolute XLA FLOPs while the static
    # fallback is a DOF-x-substeps proxy — different units.  Use the
    # measurements only when every member WITHOUT an explicit override has
    # one; a partial set would mix units inside one partition and skew the
    # weights arbitrarily.  Explicit `costs` always win (the caller vouches
    # for their consistency).
    measured: dict[str, float] = {}
    if use_artifacts:
        for name, _ in named_envs:
            if (costs or {}).get(name) is None:
                c = dryrun_step_cost(name, artifact_dir)
                if c is not None:
                    measured[name] = c
    needing = {n for n, _ in named_envs if (costs or {}).get(n) is None}
    use_measured = needing and set(measured) == needing
    resolved: dict[str, float] = {}
    for name, env in named_envs:
        c = (costs or {}).get(name)
        if c is None and use_measured:
            c = measured[name]
        if c is None:
            c = static_step_cost(env)
        if c <= 0:
            raise ValueError(f"non-positive step cost for {name!r}: {c}")
        resolved[name] = float(c)
    counts = _partition([1.0 / resolved[n] for n, _ in named_envs],
                        total_envs, min_envs)
    members = tuple(
        SubFleet(name=name, env=env, n_envs=k, weight=k / total_envs,
                 cost=resolved[name])
        for (name, env), k in zip(named_envs, counts))
    return FleetSchedule(members=members)


# --- determinism bookkeeping --------------------------------------------------
def scenario_seed(base_seed: int, index: int) -> int:
    """Distinct, stable per-scenario seed for the initial-state bank (the
    orchestrator splits bank/run keys from it).

    Derived via `fold_in(PRNGKey(base_seed), index)` — a joint hash of
    (seed, index) — rather than the former additive stride
    `base_seed + 7919*(index+1)`, which collided: `(s, i+1)` and
    `(s+7919, i)` shared a seed, so two different runs could train on
    identical initial-state banks.  Pure function of its arguments; the
    replay contract only requires stability within a run lineage.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(base_seed), index)
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return int(np.asarray(key).ravel()[-1])


def rollout_key(seed_key: jax.Array, index: int, iteration) -> jax.Array:
    """The rollout key for (scenario `index`, `iteration`) — a pure function
    of the run seed, so crash replay and checkpoint resume regenerate the
    exact key sequence (`fold_in` twice, scenario first)."""
    return jax.random.fold_in(jax.random.fold_in(seed_key, index), iteration)

"""One compiled mesh program for the whole fleet (the paper's headline).

`fleet/pipeline.py` originally reproduced "hundreds of parallel
environments" as one dispatched rollout program PER SCENARIO — the XLA
queue hid cross-scenario stragglers, but nothing in the *program* did: a
slow sub-fleet serialized the device behind it.  This module merges the
whole heterogeneous fleet into ONE jitted program per iteration:

    step(params_k, opt_k, broker, k, keys_{k+1}) ->
        (params_{k+1}, opt_{k+1}, broker')

      inside the single program:
        1. update k    — consume traj_k from the broker rings, run the
                         joint multitask PPO update (non-finite guarded);
        2. rollout k+1 — every scenario's sub-fleet, laid out as a
                         scenario-major SUPER-BATCH: each scenario's env
                         batch padded up to the next multiple of the
                         `data`-axis size, the whole region
                         `shard_map`-ped over `data` so every device
                         advances a slice of EVERY scenario —
                         cross-scenario stragglers are load-balanced by
                         construction, not hidden by the dispatch queue;
        3. park        — padded trajectories are sliced back to their
                         real env counts (padding is masked out of the
                         loss by never reaching it: slicing happens
                         BEFORE GAE/advantage normalization, so pad rows
                         cannot skew the statistics; the scheduler's
                         per-scenario `weights` keep weighting the joint
                         loss exactly as before) and pushed into the
                         broker rings along with the update stats.

    Update k and rollout k+1 both read params_k — the double-buffered
    overlap `FleetRunner` used to get from two dispatches now lives inside
    one program, where XLA schedules the two dependency-free subgraphs
    itself.

Determinism: the rollout consumes the SAME per-(scenario, iteration) keys
(`scheduler.rollout_key = fold_in(fold_in(seed_key, i), k)`) and draws the
SAME random numbers as the per-scenario dispatch path — bank indices are
drawn at the REAL env count and padded afterwards, and the per-step action
noise is pre-drawn at the real count from the identical per-step key
stream, then padded.  The scan body is structurally identical to
`core/rollout.py` (which pre-draws noise as scan data for exactly this
reason), so on a single-`data`-shard mesh — where the padding is zero and
shapes match the dispatch path exactly — the super-batch rollout is
bit-identical to per-scenario dispatch (pinned by tests/test_fleet.py's
conformance test).  With real padding (a scenario's env count not
divisible by the `data` axis) the real rows stay bit-identical for
row-independent computations, but solvers whose compiled program tiles
over the batch (e.g. the fused Pallas HIT RHS) may differ at the ulp
level across batch widths — which is why padding is per-scenario minimal
rather than fleet-wide max.  The checkpoint state tree (params / opt /
broker) is unchanged in both structure and shapes either way.

Multi-host: the same program runs unmodified over a process-spanning mesh
(`launch/mesh.py: init_distributed + make_fleet_mesh`) on backends whose
runtime supports cross-process computations (TPU/GPU).  The CPU PJRT
backend does not; there, each process runs its local shard of the
collective-free rollout region (`rollout_shard`) — which is what the
multi-host CPU smoke test and the per-host scaling rows in
benchmarks/fleet_scaling.py exercise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core import policy as policy_lib
from ..core import ppo as ppo_lib
from ..envs.base import EnvState
from . import broker as broker_lib
from . import multitask


def guarded_fleet_update(params, opt_state, ppo_cfg, mcfg, trajs, weights, k):
    """Joint multitask PPO update + the in-graph non-finite guard.

    The single shared implementation behind both the per-scenario dispatch
    path (`FleetRunner._update_impl`) and the single fleet program — the
    pipelined loop never syncs to inspect stats, so the revert decision
    must ride inside the program.
    """
    new_params, new_opt, stats = multitask.fleet_update(
        params, opt_state, ppo_cfg, mcfg, trajs, weights)
    ok = jnp.all(jnp.stack([jnp.all(jnp.isfinite(v))
                            for v in jax.tree.leaves(stats)]))
    keep = lambda new, old: jax.tree.map(
        lambda a, b: jnp.where(ok, a, b), new, old)
    stats = dict(stats)
    stats["update_ok"] = ok.astype(jnp.float32)
    stats["iteration"] = k.astype(jnp.float32)
    return keep(new_params, params), keep(new_opt, opt_state), stats


def slice_traj(traj: ppo_lib.Trajectory, n_envs: int) -> ppo_lib.Trajectory:
    """Drop the padding rows: (T, B_pad, ...) -> (T, n_envs, ...)."""
    return ppo_lib.Trajectory(
        obs=traj.obs[:, :n_envs],
        actions=traj.actions[:, :n_envs],
        log_probs=traj.log_probs[:, :n_envs],
        rewards=traj.rewards[:, :n_envs],
        dones=traj.dones[:, :n_envs],
        values=traj.values[:, :n_envs],
        last_value=traj.last_value[:n_envs],
    )


_TRAJ_DATA_SPEC = ppo_lib.Trajectory(
    obs=P(None, "data"), actions=P(None, "data"), log_probs=P(None, "data"),
    rewards=P(None, "data"), dones=P(None, "data"), values=P(None, "data"),
    last_value=P("data"))


class FleetProgram:
    """The whole fleet's rollout+update iteration as one compiled program.

    Owns nothing the `FleetOrchestrator` doesn't already have — banks,
    envs, and the multitask policy come from the per-scenario
    orchestrators; this class only lays their work out as one program.
    """

    def __init__(self, forch, weights: dict[str, float],
                 ppo_cfg: ppo_lib.PPOConfig, *, mesh=None,
                 data_axis: str = "data"):
        self.forch = forch
        self.mcfg = forch.mcfg
        self.weights = weights
        self.ppo_cfg = ppo_cfg
        self.mesh = mesh
        self.data_axis = data_axis
        self.n_envs = {m.name: m.n_envs for m in forch.schedule.members}
        self.n_data = (int(mesh.shape[data_axis])
                       if mesh is not None and data_axis in mesh.shape else 1)
        # per-scenario super-batch width: padded up to the next multiple of
        # the `data` axis so shard_map splits it evenly.  Minimal padding
        # (not fleet-wide max) keeps batch shapes equal to the dispatch
        # path whenever `data` divides the env count — the precondition
        # for bit-identical conformance (see module docstring).
        self.b_pad = {n: -(-b // self.n_data) * self.n_data
                      for n, b in self.n_envs.items()}
        # one compiled program per iteration; opt state and broker rings
        # donate (their buffers update in place), params do not alias their
        # output (the guard may keep the old tree) but params_k has no
        # external reader after the call, so donation would also be sound —
        # kept undonated to match the dispatch path's audit expectations.
        self._step = jax.jit(self._step_impl, donate_argnums=(1, 2))
        self._prologue = jax.jit(self._prologue_impl, donate_argnums=(1,))

    @property
    def names(self) -> tuple[str, ...]:
        return self.forch.names

    # --- deterministic input draws -------------------------------------------
    def draw_padded_inputs(self, name: str, key: jax.Array
                           ) -> tuple[jax.Array, jax.Array]:
        """(u0, noise) for scenario `name`, padded to the super-batch width.

        Bit-compatible with the dispatch path: bank indices and per-step
        action noise are drawn at the REAL env count from the same key
        splits `Orchestrator.sample_fleet` + `rollout` use, THEN padded
        (pad rows replay bank row 0 with zero noise; they are sliced off
        before the broker/loss ever see them).
        """
        orch = self.forch.orchs[name]
        n = self.n_envs[name]
        pad = self.b_pad[name] - n
        k_init, k_roll = jax.random.split(key)
        idx = jax.random.randint(k_init, (n,), 0, orch.fleet.bank_size - 1)
        if pad:
            idx = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)])
        u0 = jnp.take(orch.bank, idx, axis=0)
        act_shape = orch.env.action_spec.shape
        step_keys = jax.random.split(k_roll, orch.env.n_actions)
        noise = jax.vmap(
            lambda kk: jax.random.normal(kk, (n,) + act_shape))(step_keys)
        if pad:
            noise = jnp.concatenate(
                [noise, jnp.zeros(noise.shape[:1] + (pad,) + act_shape,
                                  noise.dtype)], axis=1)
        return u0, noise

    # --- the shard_map-ped rollout region ------------------------------------
    def _scan_rollout(self, name: str, params: dict, u0: jax.Array,
                      noise: jax.Array) -> ppo_lib.Trajectory:
        """core/rollout.py's scan with the action noise passed in as data
        (so the noise stream is independent of the padded batch width and
        of how `data` shards it)."""
        env = self.forch.orchs[name].env
        pol = multitask.policy_fns(self.mcfg, name)
        state0 = EnvState(u=u0, t_step=jnp.zeros((u0.shape[0],), jnp.int32))

        def step_fn(state: EnvState, noise_t: jax.Array):
            obs = env.observe(state)
            mean, std = pol.dist(params, obs)
            action = mean + std * noise_t
            logp = policy_lib.log_prob(mean, std, action)
            val = pol.value(params, obs)
            res = env.step(state, action)
            return res.state, (obs, action, logp, res.reward, res.done, val)

        final_state, (obs, actions, log_probs, rewards, dones, values) = \
            jax.lax.scan(step_fn, state0, noise)
        last_value = pol.value(params, env.observe(final_state))
        return ppo_lib.Trajectory(obs=obs, actions=actions,
                                  log_probs=log_probs, rewards=rewards,
                                  dones=dones, values=values,
                                  last_value=last_value)

    def rollout_shard(self, params: dict, u0s: dict, noises: dict
                      ) -> dict[str, ppo_lib.Trajectory]:
        """Advance every scenario's (already laid-out) env batch — the body
        of the shard_map region.  Collective-free: each device touches only
        its own rows of every scenario, which is exactly what makes the
        super-batch layout straggler-proof (and lets a CPU multi-host
        smoke run one process's shard standalone)."""
        return {name: self._scan_rollout(name, params, u0s[name],
                                         noises[name])
                for name in self.names}

    def rollout_super_batch(self, params: dict, keys: dict[str, jax.Array]
                            ) -> dict[str, ppo_lib.Trajectory]:
        """One rollout pass over the whole fleet; returns PADDED
        trajectories (B_pad envs per scenario)."""
        drawn = {n: self.draw_padded_inputs(n, keys[n]) for n in self.names}
        u0s = {n: uv[0] for n, uv in drawn.items()}
        noises = {n: uv[1] for n, uv in drawn.items()}
        if self.mesh is None:
            return self.rollout_shard(params, u0s, noises)
        fn = shard_map(
            self.rollout_shard, mesh=self.mesh,
            in_specs=(P(),  # params: replicated
                      {n: P(self.data_axis) for n in self.names},
                      {n: P(None, self.data_axis) for n in self.names}),
            out_specs={n: _TRAJ_DATA_SPEC for n in self.names},
            check_rep=False)
        return fn(params, u0s, noises)

    # --- the compiled iteration ----------------------------------------------
    def _step_impl(self, params, opt_state, broker, k, keys):
        trajs_k = {n: broker_lib.latest_traj(broker, n) for n in self.names}
        new_params, new_opt, stats = guarded_fleet_update(
            params, opt_state, self.ppo_cfg, self.mcfg, trajs_k,
            self.weights, k)
        padded = self.rollout_super_batch(params, keys)
        for n in self.names:
            broker = broker_lib.push_traj(
                broker, n, slice_traj(padded[n], self.n_envs[n]))
        broker = broker_lib.push_metrics(broker, "fleet", stats)
        return new_params, new_opt, broker

    def _prologue_impl(self, params, broker, keys):
        """Iteration-0 priming: rollout + park, no update (the broker must
        hold traj_0 before the first in-program update can consume it)."""
        padded = self.rollout_super_batch(params, keys)
        for n in self.names:
            broker = broker_lib.push_traj(
                broker, n, slice_traj(padded[n], self.n_envs[n]))
        return broker

    def step(self, params, opt_state, broker, k, keys):
        """Dispatch iteration k: update k + rollout k+1 + broker pushes,
        one XLA program.  `opt_state` and `broker` are DONATED."""
        return self._step(params, opt_state, broker, k, keys)

    def prologue(self, params, broker, keys):
        """Dispatch the priming rollout for iteration 0 (`broker` donated)."""
        return self._prologue(params, broker, keys)

"""Adam(W) as a pure pytree transform.

Optimizer moments mirror the parameter pytree, so a NamedSharding computed
for a parameter applies verbatim to its m/v slots — this is what keeps the
optimizer state sharded identically to the 2D-sharded weights on the pod
mesh (see parallel/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float | None = None  # global-norm clip


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adam_init(params: Any) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=zeros,
                     v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adam_update(
    cfg: AdamConfig,
    params: Any,
    grads: Any,
    state: AdamState,
    lr: jax.Array | float | None = None,
) -> tuple[Any, AdamState]:
    """One Adam(W) step; returns (new_params, new_state)."""
    if cfg.grad_clip is not None:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr_t = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamState(step=step, m=new_m, v=new_v)

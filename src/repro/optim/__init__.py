"""Sharded-friendly optimizers (pure pytree transforms, no optax offline)."""
from .adam import AdamConfig, adam_init, adam_update, global_norm, clip_by_global_norm
from .schedules import constant_schedule, cosine_schedule, linear_warmup_cosine

__all__ = [
    "AdamConfig",
    "adam_init",
    "adam_update",
    "global_norm",
    "clip_by_global_norm",
    "constant_schedule",
    "cosine_schedule",
    "linear_warmup_cosine",
]

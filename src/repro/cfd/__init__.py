"""FLEXI substrate: DGSEM compressible Navier-Stokes LES of forced HIT."""
from .dgsem import DGParams
from .solver import HITConfig, advance_rl_interval
from .env import EnvState, StepResult, observe, reset_from_bank, reset_random, step

__all__ = [
    "DGParams",
    "HITConfig",
    "advance_rl_interval",
    "EnvState",
    "StepResult",
    "observe",
    "reset_from_bank",
    "reset_random",
    "step",
]

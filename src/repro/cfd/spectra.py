"""Energy spectra: nodal->uniform interpolation, shell-averaged FFT spectrum,
and the synthetic von Karman-Pao reference spectrum standing in for the
paper's DNS ground truth (see DESIGN.md assumption ledger)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import gll
from .dgsem import DGParams
from .equations import conservative_to_primitive
from .solver import HITConfig


def nodal_to_uniform(q: jax.Array, dg: DGParams) -> jax.Array:
    """Interpolate nodal DG field (..., K,K,K, n,n,n, C) to the globally
    uniform (cell-centered) grid (..., K*n, K*n, K*n, C)."""
    v = jnp.asarray(dg.interp_to_uniform(), dtype=q.dtype)  # (n, n)
    for axis_offset in range(3):
        axis = q.ndim - 4 + axis_offset  # node axes at -4,-3,-2
        q = jnp.moveaxis(jnp.moveaxis(q, axis, -1) @ v.T, -1, axis)
    # interleave element and node axes: (..., Kx,Ky,Kz, nx,ny,nz, C)
    nd = q.ndim
    perm = list(range(nd - 7)) + [nd - 7, nd - 4, nd - 6, nd - 3, nd - 5, nd - 2, nd - 1]
    q = jnp.transpose(q, perm)
    batch = q.shape[: nd - 7]
    k, n, c = dg.n_elem, dg.n, q.shape[-1]
    return q.reshape(batch + (k * n, k * n, k * n, c))


@functools.lru_cache(maxsize=32)
def _shell_bins(n_grid: int) -> tuple[np.ndarray, int, np.ndarray]:
    """Integer shell index |k| for an rfft 3-D grid, and the number of shells."""
    k1 = np.fft.fftfreq(n_grid, d=1.0 / n_grid)
    kr = np.fft.rfftfreq(n_grid, d=1.0 / n_grid)
    kx, ky, kz = np.meshgrid(k1, k1, kr, indexing="ij")
    k_mag = np.sqrt(kx**2 + ky**2 + kz**2)
    shells = np.rint(k_mag).astype(np.int32)
    n_shells = int(shells.max()) + 1
    # rfft stores half the spectrum: weight interior kz planes twice.
    weight = np.where((kz == 0) | (2 * kz == n_grid), 1.0, 2.0)
    return shells, n_shells, weight


def energy_spectrum(vel_uniform: jax.Array) -> jax.Array:
    """Shell-averaged kinetic-energy spectrum E(k) of (..., N,N,N,3) velocity.

    Normalized such that sum_k E(k) = 0.5 <|v|^2> (TKE).
    """
    n = vel_uniform.shape[-2]
    shells, n_shells, weight = _shell_bins(n)
    vhat = jnp.fft.rfftn(vel_uniform, axes=(-4, -3, -2)) / (n**3)
    e_density = 0.5 * jnp.sum(jnp.abs(vhat) ** 2, axis=-1) * jnp.asarray(weight)
    flat = e_density.reshape(e_density.shape[:-3] + (-1,))
    seg = jnp.asarray(shells.reshape(-1))
    spec = jax.vmap(lambda f: jax.ops.segment_sum(f, seg, num_segments=n_shells))(
        flat.reshape((-1, flat.shape[-1]))
    )
    return spec.reshape(e_density.shape[:-3] + (n_shells,))


def vkp_spectrum(k: np.ndarray, u_rms: float, k_peak: float, k_eta: float) -> np.ndarray:
    """von Karman-Pao model spectrum, normalized to integrate (over the
    discrete shells) to 1.5 u_rms^2 — the synthetic E_DNS(k)."""
    k = np.asarray(k, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        shape = (k / k_peak) ** 4 / (1.0 + (k / k_peak) ** 2) ** (17.0 / 6.0)
        spec = shape * np.exp(-2.0 * (k / k_eta) ** 2)
    spec = np.where(k > 0, spec, 0.0)
    tke = 1.5 * u_rms**2
    spec = spec * (tke / max(np.sum(spec), 1e-300))
    return spec


def reference_spectrum(cfg: HITConfig) -> np.ndarray:
    """E_DNS(k) on the shells of the LES grid (index = integer wavenumber)."""
    n_grid = cfg.dg.n_dof_dir
    _, n_shells, _ = _shell_bins(n_grid)
    k = np.arange(n_shells, dtype=np.float64)
    return vkp_spectrum(k, cfg.u_rms, cfg.k_peak, cfg.k_eta)


def les_spectrum(u: jax.Array, cfg: HITConfig) -> jax.Array:
    """Instantaneous E_LES(k) from a conservative nodal state."""
    _, vel, _, _ = conservative_to_primitive(u)
    vel_uniform = nodal_to_uniform(vel, cfg.dg)
    return energy_spectrum(vel_uniform)


def spectral_error(e_les: jax.Array, e_dns: jax.Array, k_max: int) -> jax.Array:
    """Paper Eq. (4): mean relative squared spectrum error over k in [1, k_max]."""
    sl = slice(1, k_max + 1)
    rel = (e_dns[..., sl] - e_les[..., sl]) / e_dns[..., sl]
    return jnp.mean(rel**2, axis=-1)


def reward_from_error(ell: jax.Array, alpha: float) -> jax.Array:
    """Paper Eq. (5) (sign-corrected, see DESIGN.md): r = 2 exp(-l/alpha) - 1."""
    return 2.0 * jnp.exp(-ell / alpha) - 1.0

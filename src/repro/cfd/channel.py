"""Plane-channel flow with wall-modeled LES — the non-periodic DGSEM scenario.

This is the paper group's follow-up workload (SmartFlow's headline case):
RL-controlled wall modeling in a pressure-gradient-driven channel.  The
domain is periodic in x (streamwise) and z (spanwise) and WALLED in y: the
DGSEM surface exchange along y replaces the periodic wrap with weak-form
wall fluxes built on the `dgsem.set_face`/`dgsem.left_faces` BC abstraction.

Boundary treatment (weak, flux-based — nothing is overwritten in the state):

  * advective wall flux: no-penetration; the +y Euler flux at a wall face
    reduces to a pure pressure flux [0, 0, p, 0, 0] from the interior trace,
  * viscous wall flux: wall-MODELED.  The resolved near-wall gradient is not
    trusted (that is the point of WMLES); instead the tangential stress
    tau_w = rho u_tau^2 comes from inverting Reichardt's law of the wall at
    a matching point inside the wall-adjacent element, and the RL action
    scales it per wall element: tau = a * tau_model, a in [0, a_max].
    Energy work and heat flux vanish at the (no-slip, adiabatic) wall,
  * BR1 gradient wall trace: interior trace with the wall-normal velocity
    zeroed (slip-like) — wall friction enters ONLY through the modeled
    flux, which keeps the under-resolved scheme free of the stiff no-slip
    lift jump,

with everything else (split-form Kennedy-Gruber volume terms, LLF interior
surfaces, BR1 viscous interfaces, Carpenter-Kennedy RK5(4)) identical to the
periodic HIT solver.  With `wall=False` every override is skipped and the
assembly IS the periodic path (tests/test_channel.py pins this against
`solver.navier_stokes_rhs`).

The flow is driven by a constant streamwise pressure-gradient forcing
f_x = u_tau_target^2 / h; the reward compares the x-z-averaged mean-velocity
profile against the Reichardt law-of-the-wall reference profile (the
log-law/DNS stand-in), mirroring the spectral-error reward of the HIT case.

State layout is the shared (..., Kx, Ky, Kz, n, n, n, 5) convention with
ANISOTROPIC element counts and lengths per direction (per-direction
jacobians through the grown `dgsem` operator signatures).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import dgsem, equations, gll
from ..kernels.ref import reichardt_uplus  # canonical formula (kernel oracle)
from .equations import GasParams
from .solver import _RK_A, _RK_B, kernel_grad_nut


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Static configuration of one wall-modeled channel-flow environment."""

    n_poly: int = 3
    n_elem: tuple[int, int, int] = (3, 4, 3)          # (Kx, Ky, Kz)
    lengths: tuple[float, float, float] = (4.0, 2.0, 2.0)
    # gas / flow
    mach: float = 0.3
    nu: float = 5e-3
    rho0: float = 1.0
    u_bulk: float = 1.0        # velocity scale (obs normalization)
    prandtl: float = 0.72
    prandtl_turb: float = 0.9
    cs_sgs: float = 0.1        # fixed interior Smagorinsky coefficient
    # wall model / forcing
    u_tau: float = 0.12        # target friction velocity; f_x = u_tau^2 / h
    kappa: float = 0.41
    wm_iters: int = 8          # fixed-point iterations inverting the wall law
    wall: bool = True          # False -> fully periodic (BC-reduction tests)
    # time stepping
    cfl: float = 0.35
    dt_rl: float = 0.1
    t_end: float = 2.0
    # reward / action
    alpha: float = 0.2         # reward shape, r = 2 exp(-l/alpha) - 1
    a_max: float = 2.0         # wall-stress scaling bound (1.0 = model as-is)
    # initial-state perturbation amplitude (fraction of u_bulk)
    perturb: float = 0.08
    # Pallas kernels for the gradient, eddy-viscosity and wall-model hot
    # spots.  None = auto (kernels.default_impl(): ON and compiled on TPU,
    # off elsewhere; overridable via REPRO_KERNELS); True/False force the
    # choice (off-TPU forced-on runs in interpret mode — the parity-test
    # configuration).
    use_kernels: bool | None = None
    # Rollout compute precision: "fp32" (default, bit-exact legacy path) or
    # "bf16" (state advanced in bfloat16 inside `advance_rl_interval`;
    # kernel-internal math, observations, reward and the PPO update stay
    # float32).  Same contract as HITConfig.precision; gated by
    # tests/test_precision.py.
    precision: str = "fp32"

    @property
    def n(self) -> int:
        return self.n_poly + 1

    @property
    def compute_dtype(self):
        """Rollout state dtype resolved from `precision` (validated here)."""
        if self.precision not in ("fp32", "bf16"):
            raise ValueError(f"unknown precision: {self.precision!r} "
                             f"(expected 'fp32' or 'bf16')")
        return jnp.bfloat16 if self.precision == "bf16" else jnp.float32

    @property
    def kernels_enabled(self) -> bool:
        """Resolved `use_kernels`: the backend policy unless forced."""
        from ..kernels.policy import resolve_use_kernels

        return resolve_use_kernels(self.use_kernels)

    @property
    def dxs(self) -> tuple[float, float, float]:
        return tuple(l / k for l, k in zip(self.lengths, self.n_elem))

    @property
    def jacs(self) -> tuple[float, float, float]:
        return tuple(2.0 / dx for dx in self.dxs)

    @property
    def half_height(self) -> float:
        return 0.5 * self.lengths[1]

    @property
    def f_x(self) -> float:
        """Constant streamwise forcing balancing the target wall stress."""
        return self.u_tau**2 / self.half_height

    @property
    def gas(self) -> GasParams:
        return GasParams(mu=self.rho0 * self.nu, prandtl=self.prandtl,
                         prandtl_turb=self.prandtl_turb)

    @property
    def sound_speed0(self) -> float:
        return self.u_bulk / self.mach

    @property
    def p0(self) -> float:
        return self.rho0 * self.sound_speed0**2 / equations.GAMMA

    @property
    def delta_filter(self) -> float:
        """LES filter width: geometric-mean node spacing."""
        dx, dy, dz = self.dxs
        return float((dx * dy * dz) ** (1.0 / 3.0)) / self.n

    @property
    def dt(self) -> float:
        """Fixed stable timestep (DG CFL ~ 1/(2N+1)) that divides dt_rl."""
        v_max = self.sound_speed0 + 3.0 * self.u_bulk
        dt_stable = self.cfl * min(self.dxs) / (v_max * (2 * self.n_poly + 1))
        n_sub = int(np.ceil(self.dt_rl / dt_stable))
        return self.dt_rl / n_sub

    @property
    def n_substeps(self) -> int:
        return int(round(self.dt_rl / self.dt))

    @property
    def n_actions(self) -> int:
        return int(round(self.t_end / self.dt_rl))

    @property
    def n_wall_elements(self) -> int:
        """Wall-adjacent elements over BOTH walls: 2 * Kx * Kz."""
        return 2 * self.n_elem[0] * self.n_elem[2]

    @property
    def tau_wall(self) -> float:
        """Target wall shear stress rho u_tau^2 — the classic wall-pressure
        normalization scale (p'_rms ~ 2-3 tau_w in channel flow)."""
        return self.rho0 * self.u_tau**2

    @property
    def t0(self) -> float:
        """Background temperature p0 / (rho0 R) — the fluctuation baseline
        for the near-wall temperature observation."""
        return self.p0 / (self.rho0 * equations.R_GAS)

    @property
    def t_tau(self) -> float:
        """Friction-temperature analog u_tau^2 / cp: the viscous-heating
        temperature scale at an adiabatic wall (the classic T_tau = q_w /
        (rho cp u_tau) degenerates to it when q_w is the frictional
        dissipation tau_w u_tau) — the temperature-channel normalization."""
        return self.u_tau**2 / equations.CP

    def operators(self) -> dict:
        _, w = gll.gll_nodes_weights(self.n_poly)
        return {
            "D": jnp.asarray(gll.lagrange_derivative_matrix(self.n_poly),
                             jnp.float32),
            "inv_w_end": (float(1.0 / w[0]), float(1.0 / w[-1])),
            "w": jnp.asarray(w, jnp.float32),
        }


# --- wall law / reference profile -------------------------------------------
# `reichardt_uplus` lives in kernels/ref.py (it is the wall-model kernel's
# oracle formula) and is re-exported here for the profile/reference users.


def node_coords(cfg: ChannelConfig, direction: int) -> np.ndarray:
    """Physical GLL node coordinates along `direction`, shape (K_d, n)."""
    x_gll, _ = gll.gll_nodes_weights(cfg.n_poly)
    dx = cfg.dxs[direction]
    offsets = (np.arange(cfg.n_elem[direction]) + 0.5) * dx
    return offsets[:, None] + 0.5 * dx * x_gll[None, :]


def reference_profile(cfg: ChannelConfig) -> np.ndarray:
    """Target mean streamwise velocity at the y GLL nodes, (Ky, n).

    Reichardt's law at the target u_tau — the synthetic log-law/DNS stand-in
    (symmetric in the two channel halves by construction).
    """
    y = node_coords(cfg, 1)
    y_dist = np.minimum(y, cfg.lengths[1] - y)
    y_plus = y_dist * cfg.u_tau / cfg.nu
    return (cfg.u_tau * reichardt_uplus(y_plus, cfg.kappa, xp=np)
            ).astype(np.float32)


def mean_velocity_profile(u: jax.Array, cfg: ChannelConfig,
                          ops: dict) -> jax.Array:
    """x-z quadrature average of streamwise velocity: (..., Ky, n)."""
    _, vel, _, _ = equations.conservative_to_primitive(u)
    ux = vel[..., 0]  # (..., Kx, Ky, Kz, ni, nj, nk)
    w = ops["w"] * 0.5
    kx, _, kz = cfg.n_elem
    return jnp.einsum("...abcijk,i,k->...bj", ux, w, w) / (kx * kz)


def profile_error(profile: jax.Array, ref: jax.Array, ops: dict) -> jax.Array:
    """Quadrature-weighted relative squared L2 error of the mean profile."""
    w = ops["w"] * 0.5
    num = jnp.einsum("...bj,j->...", (profile - ref) ** 2, w)
    den = jnp.einsum("bj,j->", ref * ref, w)
    return num / jnp.maximum(den, 1e-12)


# --- initial states ---------------------------------------------------------
def sample_initial_state(key: jax.Array, cfg: ChannelConfig) -> jax.Array:
    """One random state (Kx, Ky, Kz, n, n, n, 5): the reference profile at a
    random bulk deficit/excess (so the wall-stress action has work to do —
    over- or under-stressed walls drive the profile back toward or away from
    the target) plus a few random-phase wall-vanishing perturbation modes
    (enough to trip the nonlinearity; no DNS restart files exist offline)."""
    key, key_amp = jax.random.split(key)
    xs = [jnp.asarray(node_coords(cfg, d), jnp.float32) for d in range(3)]
    kx, ky, kz = cfg.n_elem
    n = cfg.n
    shape = (kx, ky, kz, n, n, n)
    x = jnp.broadcast_to(xs[0][:, None, None, :, None, None], shape)
    y = jnp.broadcast_to(xs[1][None, :, None, None, :, None], shape)
    z = jnp.broadcast_to(xs[2][None, None, :, None, None, :], shape)

    u_ref = jnp.asarray(reference_profile(cfg), jnp.float32)
    bulk_factor = jax.random.uniform(key_amp, (), jnp.float32, 0.75, 1.25)
    ux = jnp.broadcast_to(u_ref[None, :, None, None, :, None], shape)
    ux = ux * bulk_factor
    uy = jnp.zeros(shape, jnp.float32)
    uz = jnp.zeros(shape, jnp.float32)

    # wall-vanishing envelope; modes periodic in x/z
    env = jnp.sin(np.pi * y / cfg.lengths[1])
    lx, _, lz = cfg.lengths
    n_modes = 4
    phases = jax.random.uniform(key, (n_modes, 3), jnp.float32,
                                0.0, 2.0 * np.pi)
    amp = cfg.perturb * cfg.u_bulk
    for m, (mx, mz) in enumerate(((1, 1), (1, 2), (2, 1), (2, 2))):
        cx = 2.0 * np.pi * mx / lx
        cz = 2.0 * np.pi * mz / lz
        ux = ux + amp * env * jnp.sin(cx * x + phases[m, 0]) * jnp.cos(cz * z)
        uy = uy + amp * env * jnp.cos(cx * x + phases[m, 1]) * jnp.sin(cz * z)
        uz = uz + amp * env * jnp.sin(cz * z + phases[m, 2]) * jnp.cos(cx * x)

    rho = jnp.full(shape, cfg.rho0, jnp.float32)
    p = jnp.full(shape, cfg.p0, jnp.float32)
    vel = jnp.stack([ux, uy, uz], axis=-1)
    return equations.primitive_to_conservative(rho, vel, p)


def make_state_bank(key: jax.Array, cfg: ChannelConfig,
                    n_states: int) -> jax.Array:
    keys = jax.random.split(key, n_states)
    return jax.vmap(lambda k: sample_initial_state(k, cfg))(keys)


# --- near-wall observation fields --------------------------------------------
def wall_observation(field: jax.Array, cfg: ChannelConfig, *,
                     flip_sign_channel: int | None = None) -> jax.Array:
    """Extract + mirror the wall-adjacent element layers of a nodal field.

    field: (..., Kx, Ky, Kz, n, n, n, C) per-node quantity.  The ky=0 and
    ky=Ky-1 element layers are selected and the top wall is mirrored (y node
    axis flipped; channel `flip_sign_channel`, if given, negated — e.g. the
    wall-normal velocity) so both walls present the same orientation to a
    shared policy trunk: "away from the wall" is always increasing node
    index.  Returns (..., 2*Kx*Kz, n, n, n, C), bottom wall first.
    """
    ky_axis = field.ndim - 7 + 1  # (..., Kx, Ky, Kz, n, n, n, C)
    bot = jax.lax.index_in_dim(field, 0, ky_axis, keepdims=False)
    top = jax.lax.index_in_dim(field, field.shape[ky_axis] - 1, ky_axis,
                               keepdims=False)
    top = jnp.flip(top, axis=-3)
    if flip_sign_channel is not None:
        top = top.at[..., flip_sign_channel].multiply(-1.0)
    kx, _, kz = cfg.n_elem
    n = cfg.n
    batch = field.shape[: field.ndim - 7]
    shape = batch + (kx * kz, n, n, n, field.shape[-1])
    return jnp.concatenate([bot.reshape(shape), top.reshape(shape)], axis=-5)


def wall_velocity_observation(u: jax.Array, cfg: ChannelConfig) -> jax.Array:
    """Wall-adjacent element velocities, (..., 2*Kx*Kz, n, n, n, 3),
    UN-normalized (the env divides by its declared channel scale)."""
    _, vel, _, _ = equations.conservative_to_primitive(u)
    return wall_observation(vel, cfg, flip_sign_channel=1)


def wall_pressure_observation(u: jax.Array, cfg: ChannelConfig) -> jax.Array:
    """Near-wall static-pressure fluctuation p - p0 at the wall-adjacent
    element nodes, (..., 2*Kx*Kz, n, n, n, 1), UN-normalized (the env
    divides by `cfg.tau_wall`).  Mirrored like the velocity field so both
    walls share one orientation; pressure is a scalar, so no sign flip."""
    _, _, p, _ = equations.conservative_to_primitive(u)
    return wall_observation((p - cfg.p0)[..., None], cfg)


def wall_temperature_observation(u: jax.Array, cfg: ChannelConfig
                                 ) -> jax.Array:
    """Near-wall temperature fluctuation T - T0 at the wall-adjacent element
    nodes, (..., 2*Kx*Kz, n, n, n, 1), UN-normalized (the env divides by
    the friction-temperature scale `cfg.t_tau`).  Mirrored like pressure —
    a scalar field, no sign flip."""
    _, _, _, temp = equations.conservative_to_primitive(u)
    return wall_observation((temp - cfg.t0)[..., None], cfg)


# --- wall model -------------------------------------------------------------
def wall_stress_magnitude(u_par: jax.Array, rho_w: jax.Array, y_m: float,
                          cfg: ChannelConfig) -> jax.Array:
    """tau_w = rho u_tau^2 by inverting u_par/u_tau = u+(y_m u_tau / nu).

    Geometrically-damped fixed point: in the viscous limit (u+ ~ y+) the
    damped map lands on the exact laminar stress mu u_par / y_m in one step,
    and in the log regime it contracts; `wm_iters` iterations unroll into
    the jitted RHS.  With `cfg.kernels_enabled` the whole batched inversion
    runs as one fused Pallas launch (kernels/wall_model.py); the ref path is
    its bit-identical oracle.
    """
    from ..kernels import ops as kops

    return kops.wall_model_tau(
        u_par, jnp.broadcast_to(rho_w, jnp.shape(u_par)), y_m=y_m, nu=cfg.nu,
        kappa=cfg.kappa, iters=cfg.wm_iters,
        impl="kernel" if cfg.kernels_enabled else "ref")


def _wall_slab(arr: jax.Array, side: int) -> jax.Array:
    """Select the wall-adjacent element along y from a y-face array
    (..., Kx, Ky, Kz, n, n, C): side 0 -> ky=0, side 1 -> ky=Ky-1."""
    axis = dgsem.ELEM_AXIS[1] + arr.ndim + 1
    index = 0 if side == 0 else arr.shape[axis] - 1
    return jax.lax.index_in_dim(arr, index, axis, keepdims=False)


def _matching_state(u: jax.Array, cfg: ChannelConfig, ops: dict,
                    side: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(rho, u_x, u_z) at the wall-model matching point: the y-quadrature
    mean of the wall-adjacent element, per (x, z) face-node column.
    Shapes (..., Kx, Kz, n, n)."""
    axis = dgsem.ELEM_AXIS[1] + u.ndim
    index = 0 if side == 0 else u.shape[axis] - 1
    ue = jax.lax.index_in_dim(u, index, axis, keepdims=False)
    # (..., Kx, Kz, ni, nj, nk, 5): average the y node axis (-3)
    w = ops["w"] * 0.5
    ue = jnp.einsum("...ijkc,j->...ikc", ue, w)
    rho, vel, _, _ = equations.conservative_to_primitive(ue)
    return rho, vel[..., 0], vel[..., 2]


def wall_fluxes(u: jax.Array, scale_bot: jax.Array, scale_top: jax.Array,
                cfg: ChannelConfig, ops: dict
                ) -> tuple[jax.Array, jax.Array]:
    """Combined (advective - viscous) +y numerical flux at the two wall
    faces, each (..., Kx, Kz, n, n, 5).

    scale_bot/scale_top: RL wall-stress scaling at face nodes,
    (..., Kx, Kz, n, n) — broadcast from the per-wall-element action.
    """
    lo_tr, hi_tr = dgsem._face_slices(u, 1)
    u_wall = (_wall_slab(lo_tr, 0), _wall_slab(hi_tr, 1))
    y_m = 0.5 * cfg.dxs[1]  # matching point: wall-element centroid distance
    out = []
    for side, scale in ((0, scale_bot), (1, scale_top)):
        _, _, p_w, _ = equations.conservative_to_primitive(u_wall[side])
        rho_m, ux_m, uz_m = _matching_state(u, cfg, ops, side)
        u_par = jnp.sqrt(ux_m**2 + uz_m**2 + 1e-12)
        tau = scale * wall_stress_magnitude(u_par, rho_m, y_m, cfg)
        # stress acts along the matching-point tangential direction; the
        # +y-flux component tau_xy is positive at the bottom wall (du/dy>0
        # for flow in +x) and negative at the top — sign s flips per side.
        s = 1.0 if side == 0 else -1.0
        tau_x = s * tau * ux_m / u_par
        tau_z = s * tau * uz_m / u_par
        zero = jnp.zeros_like(p_w)
        # advective: no-penetration pressure flux; viscous: modeled stress,
        # zero wall work (no-slip) and zero heat flux (adiabatic)
        f_adv = jnp.stack([zero, zero, p_w, zero, zero], axis=-1)
        f_visc = jnp.stack([zero, tau_x, zero, tau_z, zero], axis=-1)
        out.append(f_adv - f_visc)
    return out[0], out[1]


# --- RHS / stepping ---------------------------------------------------------
def channel_rhs(u: jax.Array, scale_bot: jax.Array, scale_top: jax.Array,
                cfg: ChannelConfig, ops: dict) -> jax.Array:
    """-div(F_adv - F_visc) + pressure-gradient forcing, with wall BCs in y.

    Identical assembly to `solver.navier_stokes_rhs` (split-form
    Kennedy-Gruber volume terms, LLF surfaces, BR1 viscous interfaces) with
    the y-direction surface exchange routed through the dgsem BC helpers;
    `cfg.wall=False` skips every override and reduces to the periodic path.
    """
    gas = cfg.gas
    d_matrix, inv_w_end = ops["D"], ops["inv_w_end"]

    rho, vel, p, temp = equations.conservative_to_primitive(u)
    e_spec = u[..., 4] / rho
    prim = (rho, vel, p, e_spec)
    q_prim = jnp.concatenate([vel, temp[..., None]], axis=-1)

    bc_grad = None
    if cfg.wall:
        # gradient wall trace: interior trace with v_y zeroed (slip-like);
        # wall friction is injected only through the modeled viscous flux.
        lo_tr, hi_tr = dgsem._face_slices(q_prim, 1)
        q_lo = _wall_slab(lo_tr, 0).at[..., 1].set(0.0)
        q_hi = _wall_slab(hi_tr, 1).at[..., 1].set(0.0)
        bc_grad = (None, (q_lo, q_hi), None)
    cs_nodes = jnp.full(u.shape[:-1], cfg.cs_sgs, u.dtype)
    if cfg.kernels_enabled:
        # fused Pallas hot spots, shared with solver.navier_stokes_rhs: the
        # BC-aware surface lift composes with the kernel volume derivatives
        # through dg_gradient's vol_derivs hook.
        grad_prim, nu_t = kernel_grad_nut(q_prim, cs_nodes, d_matrix,
                                          inv_w_end, cfg.delta_filter,
                                          jac=cfg.jacs, bc=bc_grad)
        grad_v = grad_prim[..., 0:3, :]
    else:
        grad_prim = dgsem.dg_gradient(q_prim, None, d_matrix, inv_w_end,
                                      jac=cfg.jacs, bc=bc_grad)
        grad_v = grad_prim[..., 0:3, :]
        s_mag = equations.strain_magnitude(equations.strain_rate(grad_v))
        nu_t = equations.eddy_viscosity(cs_nodes, cfg.delta_filter, s_mag)

    if cfg.wall:
        g_lo, g_hi = wall_fluxes(u, scale_bot, scale_top, cfg, ops)

    rhs = None
    for d in range(3):
        # --- advective: split-form volume + LLF surface -------------------
        vol_adv = dgsem.flux_differencing(
            prim, equations.kennedy_gruber_flux, d_matrix, d
        )
        f_adv_nodes = equations.advective_flux(u, d)
        u_left, u_right = dgsem.neighbor_traces(u, d)
        f_star_adv = equations.lax_friedrichs_flux(u_left, u_right, d)
        # --- viscous: standard derivative volume + central surface --------
        f_visc = equations.viscous_flux(u, grad_prim, nu_t, gas, d)
        vol_visc = dgsem.deriv_along(f_visc, d_matrix, d)
        fv_left, fv_right = dgsem.neighbor_traces(f_visc, d)
        f_star_visc = 0.5 * (fv_left + fv_right)

        vol = vol_adv - vol_visc
        f_star = f_star_adv - f_star_visc
        f_nodes = f_adv_nodes - f_visc
        lo, hi = dgsem._face_slices(f_nodes, d)
        if d == 1 and cfg.wall:
            # non-periodic y: the wrapped faces are replaced by wall fluxes
            f_star = dgsem.set_face(f_star, d, -1, g_hi)
            f_star_left = dgsem.left_faces(f_star, d, lo_value=g_lo)
        else:
            f_star_left = dgsem.left_faces(f_star, d)  # periodic wrap
        div_d = dgsem.surface_lift(vol, f_star - hi, f_star_left - lo, d,
                                   inv_w_end)
        div_d = div_d * cfg.jacs[d]
        rhs = -div_d if rhs is None else rhs - div_d

    # --- constant streamwise pressure-gradient forcing ----------------------
    f_mom_x = rho * cfg.f_x
    f_e = f_mom_x * vel[..., 0]
    zero = jnp.zeros_like(f_mom_x)
    forcing = jnp.stack([zero, f_mom_x, zero, zero, f_e], axis=-1)
    return rhs + forcing


def rk_substep(u: jax.Array, scale_bot: jax.Array, scale_top: jax.Array,
               cfg: ChannelConfig, ops: dict) -> jax.Array:
    """One Carpenter-Kennedy RK5(4) low-storage step of size cfg.dt."""
    dt = jnp.asarray(cfg.dt, dtype=u.dtype)
    du = jnp.zeros_like(u)
    for stage in range(5):
        # cast + float(): keep the carry in the rollout compute dtype (the
        # bf16 path; both are no-ops for fp32 — see solver.rk_substep)
        rhs = channel_rhs(u, scale_bot, scale_top, cfg, ops).astype(u.dtype)
        du = float(_RK_A[stage]) * du + dt * rhs
        u = u + float(_RK_B[stage]) * du
    return u


@functools.partial(jax.jit, static_argnames=("cfg",))
def advance_rl_interval(u: jax.Array, scale_bot: jax.Array,
                        scale_top: jax.Array,
                        cfg: ChannelConfig) -> jax.Array:
    """Advance the channel LES by Delta t_RL under fixed wall-stress scaling
    (one MDP transition).  u: (..., Kx,Ky,Kz,n,n,n,5); scale_bot/scale_top:
    per-wall-element scaling (..., Kx, Kz), broadcast to face nodes here.
    With `cfg.precision == "bf16"` the state advances in bfloat16 and is
    cast back to float32 at the boundary (obs/reward/PPO stay float32)."""
    ops = cfg.operators()
    n = cfg.n
    to_nodes = lambda s: jnp.broadcast_to(s[..., None, None],
                                          s.shape + (n, n))
    sb, st = to_nodes(scale_bot), to_nodes(scale_top)
    dtype = cfg.compute_dtype
    u, sb, st = u.astype(dtype), sb.astype(dtype), st.astype(dtype)
    if dtype != jnp.float32:
        # operator matrices must follow the compute dtype or every DG
        # contraction re-promotes the bf16 carry to f32 mid-loop (JAX002)
        ops = dict(ops, D=ops["D"].astype(dtype), w=ops["w"].astype(dtype))

    def body(u, _):
        return rk_substep(u, sb, st, cfg, ops), None

    u, _ = jax.lax.scan(body, u, None, length=cfg.n_substeps)
    return u.astype(jnp.float32)

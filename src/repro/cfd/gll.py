"""Gauss-Lobatto-Legendre (GLL) quadrature machinery for the DGSEM solver.

Everything in this module is *config-time* numpy: nodes, weights, derivative
and interpolation operators are computed once per configuration and baked into
the jitted solver as constants (they are tiny: (N+1)x(N+1)).

References: Kopriva, "Implementing Spectral Methods for PDEs" (2009);
FLEXI (Krais et al. 2021) uses the same collocated GLL-DGSEM operators.
"""
from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "gll_nodes_weights",
    "lagrange_derivative_matrix",
    "lagrange_interpolation_matrix",
    "equispaced_nodes",
    "fourier_eval_matrix",
]


def _legendre_and_derivative(n: int, x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Legendre polynomial P_n(x) and derivative P'_n(x) via recurrence."""
    p_nm2 = np.ones_like(x)
    p_nm1 = x.copy()
    if n == 0:
        return p_nm2, np.zeros_like(x)
    if n == 1:
        return p_nm1, np.ones_like(x)
    for k in range(2, n + 1):
        p_n = ((2 * k - 1) * x * p_nm1 - (k - 1) * p_nm2) / k
        p_nm2, p_nm1 = p_nm1, p_n
    dp_n = n * (x * p_nm1 - p_nm2) / (x**2 - 1.0 + 1e-300)
    return p_nm1, dp_n


@functools.lru_cache(maxsize=64)
def gll_nodes_weights(n_poly: int) -> tuple[np.ndarray, np.ndarray]:
    """Nodes and quadrature weights of the (n_poly+1)-point GLL rule on [-1, 1].

    The interior nodes are the roots of P'_N; endpoints are +-1.
    Weights: w_i = 2 / (N(N+1) P_N(x_i)^2).
    """
    n = n_poly
    if n < 1:
        raise ValueError("polynomial degree must be >= 1")
    # Chebyshev-Gauss-Lobatto initial guess, then Newton on (1-x^2) P'_N(x).
    x = -np.cos(np.pi * np.arange(n + 1) / n)
    for _ in range(100):
        p, dp = _legendre_and_derivative(n, x)
        # q(x) = (1 - x^2) P'_N(x); roots of q are the GLL nodes.
        # q'(x) = -2x P'_N + (1-x^2) P''_N; use Legendre ODE:
        # (1-x^2) P''_N = 2x P'_N - N(N+1) P_N  =>  q' = -N(N+1) P_N
        q = (1.0 - x**2) * dp
        dq = -n * (n + 1) * p
        dx = np.where(np.abs(dq) > 0, q / dq, 0.0)
        x = x - dx
        if np.max(np.abs(dx)) < 1e-15:
            break
    x[0], x[-1] = -1.0, 1.0
    x = np.sort(x)
    p, _ = _legendre_and_derivative(n, x)
    w = 2.0 / (n * (n + 1) * p**2)
    return x, w


def _barycentric_weights(x: np.ndarray) -> np.ndarray:
    n = len(x)
    w = np.ones(n)
    for j in range(n):
        for i in range(n):
            if i != j:
                w[j] /= x[j] - x[i]
    return w


@functools.lru_cache(maxsize=64)
def lagrange_derivative_matrix(n_poly: int) -> np.ndarray:
    """D_ij = l'_j(x_i) for the Lagrange basis on the GLL nodes."""
    x, _ = gll_nodes_weights(n_poly)
    wb = _barycentric_weights(x)
    n = n_poly + 1
    d = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i != j:
                d[i, j] = wb[j] / wb[i] / (x[i] - x[j])
        d[i, i] = -np.sum(d[i, :])
    return d


def lagrange_interpolation_matrix(x_from: np.ndarray, x_to: np.ndarray) -> np.ndarray:
    """V_ij = l_j(x_to_i): interpolates nodal values on x_from to points x_to."""
    wb = _barycentric_weights(np.asarray(x_from, dtype=np.float64))
    x_from = np.asarray(x_from, dtype=np.float64)
    x_to = np.asarray(x_to, dtype=np.float64)
    v = np.zeros((len(x_to), len(x_from)))
    for i, xt in enumerate(x_to):
        diff = xt - x_from
        exact = np.where(np.abs(diff) < 1e-14)[0]
        if len(exact):
            v[i, exact[0]] = 1.0
        else:
            t = wb / diff
            v[i, :] = t / np.sum(t)
    return v


def equispaced_nodes(n_points: int) -> np.ndarray:
    """Cell-centered equispaced points on [-1, 1] (n_points of them).

    Cell-centered (not including endpoints) so that assembling K elements of
    n_points each gives a globally uniform, periodic-FFT-ready grid.
    """
    return -1.0 + (2.0 * np.arange(n_points) + 1.0) / n_points


def fourier_eval_matrix(n_modes: int, x_target: np.ndarray, length: float) -> np.ndarray:
    """Complex matrix E (len(x_target) x n_modes) evaluating a 1-D Fourier
    series with `n_modes` standard FFT-ordered modes at arbitrary points
    x_target in [0, length).  u(x) = sum_k uhat_k exp(2 pi i k x / L) / n.
    """
    k = np.fft.fftfreq(n_modes, d=1.0 / n_modes)  # integer wavenumbers
    phase = 2.0j * np.pi * np.outer(x_target, k) / length
    return np.exp(phase) / n_modes

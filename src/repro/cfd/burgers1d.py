"""Forced 1-D viscous Burgers DGSEM — the second RL control scenario.

Burgers turbulence is the classical 1-D testbed for subgrid modeling: an
under-resolved Burgers LES needs an eddy viscosity to keep the k^-2 shock
spectrum from piling up at the grid cutoff, exactly the role the Smagorinsky
C_s plays in the 3-D HIT case.  The RL action here is a per-element
eddy-viscosity coefficient C with nu_t = (C * Delta)^2 |du/dx| (the 1-D
Smagorinsky analog); the reward is the same spectral-error metric (paper
Eqs. 4-5) against a synthetic k^-2 reference spectrum.

The discretization reuses the GLL machinery of the 3-D solver at 1-D:

  * nodal layout u.shape = (..., K, n, 1) — element axis -3, GLL node axis
    -2, channel axis last; `...` carries the environment batch,
  * split-form volume terms with the entropy-conservative Burgers two-point
    flux f#(a, b) = (a^2 + a b + b^2) / 6 (the 1-D counterpart of the
    Kennedy-Gruber stabilization in solver.py), local Lax-Friedrichs
    surface fluxes, BR1 central viscous interfaces,
  * the same Carpenter-Kennedy RK5(4) low-storage integrator,
  * Lundgren-style linear forcing of the velocity fluctuations with a
    proportional energy controller, so the "turbulence" is statistically
    stationary over an episode.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import gll
from .solver import _RK_A, _RK_B


@dataclasses.dataclass(frozen=True)
class BurgersConfig:
    """Static configuration of one forced Burgers LES environment."""

    n_poly: int = 7
    n_elem: int = 12
    length: float = 2.0 * np.pi
    # flow
    nu: float = 5e-3
    u_rms: float = 1.0
    # forcing (linear forcing + energy proportional controller)
    forcing_a0: float = 0.3
    # time stepping
    cfl: float = 0.35
    dt_rl: float = 0.1
    t_end: float = 5.0
    # reward (same form as paper Table 1)
    k_max: int = 12
    alpha: float = 0.4
    c_max: float = 0.5        # per-element eddy-viscosity coefficient bound
    # synthetic reference spectrum: E(k) ~ k^-2 exp(-2 (k/k_eta)^2)
    k_eta: float = 24.0

    @property
    def n(self) -> int:
        return self.n_poly + 1

    @property
    def dx(self) -> float:
        return self.length / self.n_elem

    @property
    def jac(self) -> float:
        return 2.0 / self.dx

    @property
    def n_dof(self) -> int:
        return self.n_elem * self.n

    @property
    def k_energy(self) -> float:
        """Target energy 1/2 u_rms^2 (1-D: one velocity component)."""
        return 0.5 * self.u_rms**2

    @property
    def delta_filter(self) -> float:
        return self.dx / self.n

    @property
    def dt(self) -> float:
        """Fixed stable timestep (DG CFL ~ 1/(2N+1)) that divides dt_rl."""
        v_max = 4.0 * self.u_rms  # Burgers wave speed ~ max|u|
        dt_stable = self.cfl * self.dx / (v_max * (2 * self.n_poly + 1))
        n_sub = int(np.ceil(self.dt_rl / dt_stable))
        return self.dt_rl / n_sub

    @property
    def n_substeps(self) -> int:
        return int(round(self.dt_rl / self.dt))

    @property
    def n_actions(self) -> int:
        return int(round(self.t_end / self.dt_rl))

    def operators(self) -> dict:
        _, w = gll.gll_nodes_weights(self.n_poly)
        return {
            "D": jnp.asarray(gll.lagrange_derivative_matrix(self.n_poly),
                             jnp.float32),
            "inv_w_end": (float(1.0 / w[0]), float(1.0 / w[-1])),
            "w": jnp.asarray(w, jnp.float32),
        }


# --- spectra ---------------------------------------------------------------
def nodal_to_uniform(u: jax.Array, cfg: BurgersConfig) -> jax.Array:
    """Interpolate nodal field (..., K, n, 1) to the cell-centered uniform
    grid (..., K*n) — the FFT-ready 1-D grid."""
    x_gll, _ = gll.gll_nodes_weights(cfg.n_poly)
    v = jnp.asarray(
        gll.lagrange_interpolation_matrix(x_gll, gll.equispaced_nodes(cfg.n)),
        u.dtype,
    )
    q = jnp.einsum("ij,...kjc->...kic", v, u)[..., 0]   # (..., K, n)
    return q.reshape(q.shape[:-2] + (cfg.n_dof,))


def energy_spectrum(u_uniform: jax.Array) -> jax.Array:
    """Shell spectrum E(k) of (..., N) velocity, sum_k E(k) = 1/2 <u^2>."""
    n = u_uniform.shape[-1]
    uhat = jnp.fft.rfft(u_uniform, axis=-1) / n
    weight = np.full(n // 2 + 1, 2.0)  # repro-lint: disable=AST001 -- static rfft shell-weight table (shape-only input)
    weight[0] = 1.0
    if n % 2 == 0:
        weight[-1] = 1.0
    return 0.5 * jnp.abs(uhat) ** 2 * jnp.asarray(weight, u_uniform.dtype)


def reference_spectrum(cfg: BurgersConfig) -> np.ndarray:
    """Synthetic target E(k) ~ k^-2 exp(-2(k/k_eta)^2), normalized so the
    discrete shells integrate to 1/2 u_rms^2 — the Burgers-turbulence analog
    of the von Karman-Pao DNS stand-in."""
    k = np.arange(cfg.n_dof // 2 + 1, dtype=np.float64)
    with np.errstate(divide="ignore"):
        spec = np.where(k > 0, k**-2.0, 0.0) * np.exp(-2.0 * (k / cfg.k_eta) ** 2)
    spec = spec * (cfg.k_energy / max(np.sum(spec), 1e-300))
    return spec


def les_spectrum(u: jax.Array, cfg: BurgersConfig) -> jax.Array:
    return energy_spectrum(nodal_to_uniform(u, cfg))


# --- initial states --------------------------------------------------------
@functools.lru_cache(maxsize=16)
def _fourier_to_gll_matrix(cfg: BurgersConfig) -> np.ndarray:
    """Complex (K*n, n_dof) matrix evaluating the uniform-grid Fourier series
    at the global GLL coordinates."""
    x_gll, _ = gll.gll_nodes_weights(cfg.n_poly)
    offsets = (np.arange(cfg.n_elem) + 0.5) * cfg.dx
    coords = (offsets[:, None] + 0.5 * cfg.dx * x_gll[None, :]).reshape(-1)
    return gll.fourier_eval_matrix(cfg.n_dof, coords, cfg.length)


def sample_initial_state(key: jax.Array, cfg: BurgersConfig) -> jax.Array:
    """One random state (K, n, 1): random-phase field with the exact target
    spectrum on the uniform grid, evaluated at the GLL nodes (1-D Rogallo)."""
    n_grid = cfg.n_dof
    e_target = jnp.asarray(reference_spectrum(cfg), jnp.float32)
    n_half = n_grid // 2 + 1
    theta = jax.random.uniform(key, (n_half,), jnp.float32, 0.0, 2.0 * np.pi)
    # E(k) = |uhat_k/n|^2 for interior shells (weight 2) -> amplitude sqrt(E)
    amp = jnp.sqrt(e_target)
    amp = amp.at[0].set(0.0)
    if n_grid % 2 == 0:
        amp = amp.at[-1].set(0.0)  # drop the sign-ambiguous Nyquist mode
    vhat = amp * jnp.exp(1j * theta.astype(jnp.complex64))
    # full FFT ordering with Hermitian symmetry; fourier_eval_matrix divides
    # by n, so scale back up to FFT convention
    full = jnp.zeros((n_grid,), jnp.complex64)
    full = full.at[:n_half].set(vhat * n_grid)
    full = full.at[n_grid - jnp.arange(1, n_half)].set(
        jnp.conj(vhat[1:] * n_grid))
    mat = jnp.asarray(_fourier_to_gll_matrix(cfg), jnp.complex64)
    u_gll = jnp.real(mat @ full).astype(jnp.float32)
    return u_gll.reshape(cfg.n_elem, cfg.n, 1)


def make_state_bank(key: jax.Array, cfg: BurgersConfig, n_states: int) -> jax.Array:
    keys = jax.random.split(key, n_states)
    return jax.vmap(lambda k: sample_initial_state(k, cfg))(keys)


# --- solver ----------------------------------------------------------------
def _surface_lift(vol: jax.Array, jump_right: jax.Array, jump_left: jax.Array,
                  inv_w_end: tuple[float, float]) -> jax.Array:
    """Strong-form DGSEM surface correction along the (last) node axis."""
    inv_w0, inv_wn = inv_w_end
    vol = vol.at[..., -1].add(inv_wn * jump_right)
    vol = vol.at[..., 0].add(-inv_w0 * jump_left)
    return vol


def dg_gradient(us: jax.Array, cfg: BurgersConfig, ops: dict) -> jax.Array:
    """BR1 gradient du/dx of nodal scalar field us (..., K, n)."""
    vol = jnp.einsum("ij,...j->...i", ops["D"], us)
    lo, hi = us[..., 0], us[..., -1]
    u_right = jnp.roll(lo, shift=-1, axis=-1)       # neighbor across face e|e+1
    u_star_right = 0.5 * (hi + u_right)
    u_star_left = jnp.roll(u_star_right, shift=1, axis=-1)
    du = _surface_lift(vol, u_star_right - hi, u_star_left - lo,
                       ops["inv_w_end"])
    return du * cfg.jac


def burgers_rhs(us: jax.Array, c_nodes: jax.Array, cfg: BurgersConfig,
                ops: dict) -> jax.Array:
    """-d/dx(u^2/2 - nu_eff du/dx) + forcing on nodal field us (..., K, n)."""
    d_matrix = ops["D"]
    # --- advective: entropy-conservative split form + LLF surface ----------
    a, b = us[..., :, None], us[..., None, :]
    f_sharp = (a * a + a * b + b * b) / 6.0
    vol_adv = 2.0 * jnp.einsum("ij,...ij->...i", d_matrix, f_sharp)
    lo, hi = us[..., 0], us[..., -1]
    u_right = jnp.roll(lo, shift=-1, axis=-1)
    lam = jnp.maximum(jnp.abs(hi), jnp.abs(u_right))
    f_star_adv = 0.25 * (hi**2 + u_right**2) - 0.5 * lam * (u_right - hi)
    # --- viscous: BR1 gradient, eddy viscosity, central surface ------------
    du = dg_gradient(us, cfg, ops)
    nu_t = (c_nodes * cfg.delta_filter) ** 2 * jnp.abs(du)
    f_visc = (cfg.nu + nu_t) * du
    vol_visc = jnp.einsum("ij,...j->...i", d_matrix, f_visc)
    fv_lo, fv_hi = f_visc[..., 0], f_visc[..., -1]
    f_star_visc = 0.5 * (fv_hi + jnp.roll(fv_lo, shift=-1, axis=-1))
    # --- combined strong-form divergence -----------------------------------
    vol = vol_adv - vol_visc
    f_nodes_lo = 0.5 * lo**2 - fv_lo
    f_nodes_hi = 0.5 * hi**2 - fv_hi
    f_star = f_star_adv - f_star_visc
    f_star_left = jnp.roll(f_star, shift=1, axis=-1)
    div = _surface_lift(vol, f_star - f_nodes_hi, f_star_left - f_nodes_lo,
                        ops["inv_w_end"]) * cfg.jac
    rhs = -div
    # --- linear forcing on fluctuations with energy controller -------------
    w = ops["w"] * 0.5  # reference [-1, 1] -> unit mass
    u_mean = jnp.einsum("...kj,j->...", us, w) / cfg.n_elem
    fluct = us - u_mean[..., None, None]
    k_now = 0.5 * jnp.einsum("...kj,j->...", us**2, w) / cfg.n_elem
    a_eff = cfg.forcing_a0 * jnp.clip(
        cfg.k_energy / jnp.maximum(k_now, 0.1 * cfg.k_energy), 0.0, 3.0)
    return rhs + a_eff[..., None, None] * fluct


def rk_substep(us: jax.Array, c_nodes: jax.Array, cfg: BurgersConfig,
               ops: dict) -> jax.Array:
    """One Carpenter-Kennedy RK5(4) low-storage step of size cfg.dt."""
    dt = jnp.asarray(cfg.dt, us.dtype)
    du = jnp.zeros_like(us)
    for stage in range(5):
        rhs = burgers_rhs(us, c_nodes, cfg, ops)
        du = _RK_A[stage] * du + dt * rhs
        us = us + _RK_B[stage] * du
    return us


@functools.partial(jax.jit, static_argnames=("cfg",))
def advance_rl_interval(u: jax.Array, c_elem: jax.Array,
                        cfg: BurgersConfig) -> jax.Array:
    """Advance the Burgers LES by Delta t_RL under fixed per-element C
    (one MDP transition).  u: (..., K, n, 1), c_elem: (..., K)."""
    ops = cfg.operators()
    c_nodes = jnp.broadcast_to(c_elem[..., None], c_elem.shape + (cfg.n,))

    def body(us, _):
        return rk_substep(us, c_nodes, cfg, ops), None

    us, _ = jax.lax.scan(body, u[..., 0], None, length=cfg.n_substeps)
    return us[..., None]

"""The HIT LES reinforcement-learning environment (paper Sec. 5.2).

State  : coarse-scale conservative flow field on the DG mesh.
Obs    : per-element velocity nodal values, (K^3, n, n, n, 3), u_rms-normalized.
Action : per-element Smagorinsky coefficient C_s in [0, cs_max], (K^3,).
Reward : paper Eqs. (4)-(5) against the reference spectrum.

Pure-functional API (reset/step are jit/vmap/shard_map friendly); batching over
environments is done OUTSIDE by the orchestrator — mirroring the paper where
each FLEXI instance is an independent MPI job.

These free functions are the HIT *kernel*; the generic training stack talks
to them through the solver-agnostic adapter `repro.envs.hit_les.HITLESEnv`
(`envs.make("hit_les_24dof")`), which pins these numerics bit-for-bit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import initial, solver, spectra
from .equations import conservative_to_primitive
from .solver import HITConfig


class EnvState(NamedTuple):
    u: jax.Array          # conservative nodal state (K,K,K,n,n,n,5)
    t_step: jax.Array     # RL step counter (int32 scalar)


class StepResult(NamedTuple):
    state: EnvState
    obs: jax.Array
    reward: jax.Array
    done: jax.Array


def observe(u: jax.Array, cfg: HITConfig) -> jax.Array:
    """Element-local observations: (..., K^3, n, n, n, 3)."""
    _, vel, _, _ = conservative_to_primitive(u)
    batch = vel.shape[: vel.ndim - 7]
    k, n = cfg.n_elem, cfg.n_poly + 1
    obs = vel.reshape(batch + (k**3, n, n, n, 3))
    return obs / cfg.u_rms


def reset_from_bank(bank: jax.Array, index: jax.Array, cfg: HITConfig) -> tuple[EnvState, jax.Array]:
    """Initialize from state `index` of the device-resident bank."""
    u = jnp.take(bank, index, axis=0)
    state = EnvState(u=u, t_step=jnp.zeros((), jnp.int32))
    return state, observe(u, cfg)


def reset_random(key: jax.Array, cfg: HITConfig) -> tuple[EnvState, jax.Array]:
    u = initial.sample_initial_state(key, cfg)
    state = EnvState(u=u, t_step=jnp.zeros((), jnp.int32))
    return state, observe(u, cfg)


def step(state: EnvState, action: jax.Array, cfg: HITConfig,
         e_dns: jax.Array) -> StepResult:
    """One MDP transition: apply per-element C_s, advance Delta t_RL, reward.

    Solver blow-up guard (production fault tolerance): if the advanced state
    goes non-finite — an under-resolved LES with an exploratory C_s CAN blow
    up, the CFD analog of a crashed FLEXI instance — the transition reverts
    to the previous state and the agent receives the reward floor (-1).
    The episode stays finite, the penalty is learnable, and NaN never
    reaches the gradient (the paper's framework restarts the MPI job; here
    recovery is in-graph)."""
    cs = jnp.clip(action, 0.0, cfg.cs_max).reshape(
        action.shape[:-1] + (cfg.n_elem,) * 3
    )
    u_next = solver.advance_rl_interval(state.u, cs, cfg)
    finite = jnp.all(jnp.isfinite(u_next),
                     axis=tuple(range(u_next.ndim - 7, u_next.ndim)))  # (...,)
    u_next = jnp.where(finite[..., None, None, None, None, None, None, None],
                       u_next, state.u)
    e_les = spectra.les_spectrum(u_next, cfg)
    ell = spectra.spectral_error(e_les, e_dns, cfg.k_max)
    reward = jnp.where(finite, spectra.reward_from_error(ell, cfg.alpha), -1.0)
    t_next = state.t_step + 1
    done = t_next >= cfg.n_actions
    next_state = EnvState(u=u_next, t_step=t_next)
    return StepResult(next_state, observe(u_next, cfg), reward, done)

"""Compressible Navier-Stokes physics for the DGSEM solver.

Conservative state channels: [rho, rho*v1, rho*v2, rho*v3, E_total].
Non-dimensional setup matching the paper's HIT box: box length 2*pi,
target u_rms = 1, rho0 = 1; the Mach number sets the background pressure.

The LES closure is Smagorinsky's model (paper Eq. 3) with a *per-element*
coefficient C_s — the RL action.  `eddy_viscosity` is also provided as a
fused Pallas kernel (kernels/smagorinsky.py); this module is the reference.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

GAMMA = 1.4
R_GAS = 1.0
CP = GAMMA * R_GAS / (GAMMA - 1.0)
CV = R_GAS / (GAMMA - 1.0)


@dataclasses.dataclass(frozen=True)
class GasParams:
    mu: float  # dynamic viscosity (rho0=1 -> equals kinematic)
    prandtl: float = 0.72
    prandtl_turb: float = 0.9


def conservative_to_primitive(u: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """-> (rho, vel[...,3], pressure, temperature)."""
    rho = u[..., 0]
    vel = u[..., 1:4] / rho[..., None]
    kinetic = 0.5 * rho * jnp.sum(vel * vel, axis=-1)
    p = (GAMMA - 1.0) * (u[..., 4] - kinetic)
    temp = p / (rho * R_GAS)
    return rho, vel, p, temp


def primitive_to_conservative(rho: jax.Array, vel: jax.Array, p: jax.Array) -> jax.Array:
    mom = rho[..., None] * vel
    e_tot = p / (GAMMA - 1.0) + 0.5 * rho * jnp.sum(vel * vel, axis=-1)
    return jnp.concatenate([rho[..., None], mom, e_tot[..., None]], axis=-1)


def sound_speed(rho: jax.Array, p: jax.Array) -> jax.Array:
    return jnp.sqrt(GAMMA * p / rho)


def advective_flux(u: jax.Array, direction: int) -> jax.Array:
    """Euler flux F_d(u), channels like u."""
    rho, vel, p, _ = conservative_to_primitive(u)
    vn = vel[..., direction]
    f_rho = u[..., 1 + direction]
    f_mom = u[..., 1:4] * vn[..., None]
    f_mom = f_mom.at[..., direction].add(p)
    f_e = (u[..., 4] + p) * vn
    return jnp.concatenate([f_rho[..., None], f_mom, f_e[..., None]], axis=-1)


def lax_friedrichs_flux(u_l: jax.Array, u_r: jax.Array, direction: int) -> jax.Array:
    """Local Lax-Friedrichs (Rusanov) numerical flux for the advective part."""
    rho_l, vel_l, p_l, _ = conservative_to_primitive(u_l)
    rho_r, vel_r, p_r, _ = conservative_to_primitive(u_r)
    lam = jnp.maximum(
        jnp.abs(vel_l[..., direction]) + sound_speed(rho_l, p_l),
        jnp.abs(vel_r[..., direction]) + sound_speed(rho_r, p_r),
    )
    f_l = advective_flux(u_l, direction)
    f_r = advective_flux(u_r, direction)
    return 0.5 * (f_l + f_r) - 0.5 * lam[..., None] * (u_r - u_l)


def strain_rate(grad_v: jax.Array) -> jax.Array:
    """Symmetric rate-of-strain S_ij from velocity gradient (..., 3, 3).

    grad_v[..., i, j] = d v_i / d x_j.
    """
    return 0.5 * (grad_v + jnp.swapaxes(grad_v, -1, -2))


def strain_magnitude(s_ij: jax.Array) -> jax.Array:
    """|S| = sqrt(2 S_ij S_ij)  (paper Eq. 3)."""
    return jnp.sqrt(2.0 * jnp.sum(s_ij * s_ij, axis=(-1, -2)) + 1e-30)


def eddy_viscosity(cs: jax.Array, delta: float, s_mag: jax.Array) -> jax.Array:
    """nu_t = (C_s * Delta)^2 |S|  with per-element C_s broadcast to nodes."""
    return (cs * delta) ** 2 * s_mag


def viscous_flux(
    u: jax.Array,
    grad_prim: jax.Array,
    nu_t: jax.Array,
    gas: GasParams,
    direction: int,
) -> jax.Array:
    """Viscous + SGS flux F_v_d.

    grad_prim: gradients of (v1, v2, v3, T), shape (..., 4, 3) with the last
    axis the derivative direction.
    """
    rho, vel, _, _ = conservative_to_primitive(u)
    grad_v = grad_prim[..., 0:3, :]  # (..., 3 [component], 3 [direction])
    grad_t = grad_prim[..., 3, :]  # (..., 3)
    s_ij = strain_rate(grad_v)
    div_v = grad_v[..., 0, 0] + grad_v[..., 1, 1] + grad_v[..., 2, 2]
    mu_eff = gas.mu + rho * nu_t
    # Stress tensor tau_ij = 2 mu_eff (S_ij - 1/3 div(v) delta_ij)
    tau = 2.0 * mu_eff[..., None, None] * s_ij
    third = (2.0 / 3.0) * mu_eff * div_v
    tau = tau - third[..., None, None] * jnp.eye(3, dtype=u.dtype)
    # Heat flux with laminar + turbulent conductivities.
    k_eff = CP * (gas.mu / gas.prandtl + rho * nu_t / gas.prandtl_turb)
    q_d = -k_eff * grad_t[..., direction]
    tau_d = tau[..., :, direction]  # (..., 3)
    work = jnp.sum(tau_d * vel, axis=-1)
    zero = jnp.zeros_like(rho)
    return jnp.concatenate(
        [zero[..., None], tau_d, (work - q_d)[..., None]], axis=-1
    )


def kennedy_gruber_flux(
    prim_a: tuple[jax.Array, ...],
    prim_b: tuple[jax.Array, ...],
    direction: int,
) -> jax.Array:
    """Kennedy & Gruber kinetic-energy-preserving two-point flux.

    Used by the split-form (flux-differencing) DGSEM volume integral — the
    stabilization FLEXI relies on for underresolved turbulence (Gassner,
    Winters & Kopriva 2016).  prim_* = (rho, vel[...,3], p, e_spec) with
    e_spec = E/rho the specific total energy.

    f_rho = {rho}{u_d};  f_mom_i = {rho}{u_d}{u_i} + delta_id {p}
    f_E   = {rho}{u_d}{e} + {p}{u_d}
    """
    rho_a, vel_a, p_a, e_a = prim_a
    rho_b, vel_b, p_b, e_b = prim_b
    rho_m = 0.5 * (rho_a + rho_b)
    vel_m = 0.5 * (vel_a + vel_b)
    p_m = 0.5 * (p_a + p_b)
    e_m = 0.5 * (e_a + e_b)
    vn = vel_m[..., direction]
    f_rho = rho_m * vn
    f_mom = f_rho[..., None] * vel_m
    f_mom = f_mom.at[..., direction].add(p_m)
    f_e = f_rho * e_m + p_m * vn
    return jnp.concatenate([f_rho[..., None], f_mom, f_e[..., None]], axis=-1)


def max_wave_speed(u: jax.Array) -> jax.Array:
    rho, vel, p, _ = conservative_to_primitive(u)
    return jnp.max(jnp.linalg.norm(vel, axis=-1) + sound_speed(rho, p))

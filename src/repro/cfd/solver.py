"""HIT LES solver: RHS assembly, linear forcing and low-storage RK stepping.

This is the transition function T(s_{t+1} | a_t, s_t) of the paper's MDP:
given the current flow state and the per-element Smagorinsky coefficients
(the RL action), advance the compressible Navier-Stokes LES by Delta t_RL.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import dgsem, equations
from .dgsem import DGParams
from .equations import GasParams

# Carpenter & Kennedy (1994) five-stage fourth-order low-storage RK —
# FLEXI's default explicit integrator.
_RK_A = np.array(
    [
        0.0,
        -567301805773.0 / 1357537059087.0,
        -2404267990393.0 / 2016746695238.0,
        -3550918686646.0 / 2091501179385.0,
        -1275806237668.0 / 842570457699.0,
    ]
)
_RK_B = np.array(
    [
        1432997174477.0 / 9575080441755.0,
        5161836677717.0 / 13612068292357.0,
        1720146321549.0 / 2090206949498.0,
        3134564353537.0 / 4481467310338.0,
        2277821191437.0 / 14882151754819.0,
    ]
)


@dataclasses.dataclass(frozen=True)
class HITConfig:
    """Static configuration of one HIT LES environment (paper Table 1)."""

    n_poly: int = 5
    n_elem: int = 4
    length: float = 2.0 * np.pi
    # gas / flow
    mach: float = 0.3
    nu: float = 1.8e-3
    rho0: float = 1.0
    u_rms: float = 1.0
    prandtl: float = 0.72
    prandtl_turb: float = 0.9
    # forcing (Lundgren linear forcing + TKE proportional controller)
    forcing_a0: float = 0.3
    # time stepping
    cfl: float = 0.35
    dt_rl: float = 0.1
    t_end: float = 5.0
    # reward (paper Table 1)
    k_max: int = 9
    alpha: float = 0.4
    cs_max: float = 0.5
    # Pallas kernels: with kernels enabled the WHOLE RHS evaluation runs as
    # one fused mega-kernel launch (kernels/rhs.py — derivative, fluxes,
    # eddy viscosity, divergence and forcing with intermediates in VMEM).
    # None = auto (kernels.default_impl(): ON and compiled on TPU, off
    # elsewhere; overridable via REPRO_KERNELS); True/False force the choice
    # (off-TPU forced-on runs in interpret mode — the parity-test
    # configuration).
    use_kernels: bool | None = None
    # Rollout compute precision.  "fp32" (default) is the bit-exact legacy
    # path.  "bf16" advances the state in bfloat16 inside
    # `advance_rl_interval` — the HBM-resident state, RK accumulator and RHS
    # inputs/outputs drop to 16 bits (kernel-internal math stays float32)
    # while observations, reward reduction and the PPO update remain
    # float32.  Opt-in via e.g. `envs.make("hit_les_24dof",
    # precision="bf16")`; gated by the training-curve-equivalence test in
    # tests/test_precision.py.
    precision: str = "fp32"
    # synthetic DNS target spectrum (von Karman-Pao)
    k_peak: float = 4.0
    k_eta: float = 48.0

    @property
    def dg(self) -> DGParams:
        return DGParams(self.n_poly, self.n_elem, self.length)

    @property
    def kernels_enabled(self) -> bool:
        """Resolved `use_kernels`: the backend policy unless forced."""
        from ..kernels.policy import resolve_use_kernels

        return resolve_use_kernels(self.use_kernels)

    @property
    def compute_dtype(self):
        """Rollout state dtype resolved from `precision` (validated here)."""
        if self.precision not in ("fp32", "bf16"):
            raise ValueError(f"unknown precision: {self.precision!r} "
                             f"(expected 'fp32' or 'bf16')")
        return jnp.bfloat16 if self.precision == "bf16" else jnp.float32

    @property
    def k_tke(self) -> float:
        """Target turbulent kinetic energy 3/2 u_rms^2."""
        return 1.5 * self.u_rms**2

    @property
    def gas(self) -> GasParams:
        return GasParams(mu=self.rho0 * self.nu, prandtl=self.prandtl,
                         prandtl_turb=self.prandtl_turb)

    @property
    def sound_speed0(self) -> float:
        return self.u_rms / self.mach

    @property
    def p0(self) -> float:
        return self.rho0 * self.sound_speed0**2 / equations.GAMMA

    @property
    def delta_filter(self) -> float:
        """LES filter width: element size over number of nodes per direction."""
        return self.dg.dx / (self.n_poly + 1)

    @property
    def dt(self) -> float:
        """Fixed stable timestep (DG CFL ~ 1/(2N+1)) that divides dt_rl."""
        v_max = self.sound_speed0 + 3.0 * self.u_rms
        dt_stable = self.cfl * self.dg.dx / (v_max * (2 * self.n_poly + 1))
        n_sub = int(np.ceil(self.dt_rl / dt_stable))
        return self.dt_rl / n_sub

    @property
    def n_substeps(self) -> int:
        return int(round(self.dt_rl / self.dt))

    @property
    def n_actions(self) -> int:
        return int(round(self.t_end / self.dt_rl))

    def operators(self) -> dict:
        """Jit-constant operator matrices."""
        dg = self.dg
        _, w = dg.nodes_weights()
        return {
            "D": jnp.asarray(dg.deriv_matrix(), dtype=jnp.float32),
            "inv_w_end": (float(1.0 / w[0]), float(1.0 / w[-1])),
            "w": jnp.asarray(w, dtype=jnp.float32),
        }


def kernel_grad_nut(
    q_prim: jax.Array,
    cs_nodes: jax.Array,
    d_matrix: jax.Array,
    inv_w_end: tuple[float, float],
    delta: float,
    *,
    dg: DGParams | None = None,
    jac=None,
    bc: tuple | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused Pallas hot spots shared by the HIT and channel RHS assemblies:
    one-HBM-pass 3-direction volume derivative feeding the (optionally
    BC-aware) DG gradient, and the fused strain -> nu_t chain.  `dg`/`jac`/
    `bc` forward to dgsem.dg_gradient; the jnp branches of the callers are
    the validated oracle (tests/test_kernel_parity.py)."""
    from ..kernels import ops as kops

    n = q_prim.shape[-2]
    qb = q_prim.reshape((-1, n, n, n, q_prim.shape[-1]))
    vols = kops.dg_derivative3(qb, d_matrix, impl="kernel")
    vol_derivs = tuple(v.reshape(q_prim.shape) for v in vols)
    grad_prim = dgsem.dg_gradient(q_prim, dg, d_matrix, inv_w_end,
                                  vol_derivs=vol_derivs, jac=jac, bc=bc)
    grad_v = grad_prim[..., 0:3, :]
    nu_t = kops.smagorinsky_nut(
        grad_v.reshape((-1, 3, 3)), cs_nodes.reshape((-1,)), delta,
        impl="kernel",
    ).reshape(cs_nodes.shape)
    return grad_prim, nu_t


def broadcast_cs(cs_elem: jax.Array, cfg: HITConfig) -> jax.Array:
    """Per-element coefficients (..., K,K,K) -> nodal field (..., K,K,K,n,n,n)."""
    n = cfg.n_poly + 1
    return jnp.broadcast_to(
        cs_elem[..., None, None, None],
        cs_elem.shape + (n, n, n),
    )


def rhs_gradients(
    q_prim: jax.Array, cs_nodes: jax.Array, cfg: HITConfig, ops: dict
) -> tuple[jax.Array, jax.Array]:
    """Stage 1 of the unfused RHS: BR1 gradient of (v, T) + Smagorinsky
    nu_t.  Exposed as a stage so benchmarks/perf_compare.py can time the
    separate-dispatch (per-stage jit, HBM round-trip) assembly the fused
    mega-kernel replaces."""
    d_matrix, inv_w_end = ops["D"], ops["inv_w_end"]
    grad_prim = dgsem.dg_gradient(q_prim, cfg.dg, d_matrix, inv_w_end)
    grad_v = grad_prim[..., 0:3, :]
    s_mag = equations.strain_magnitude(equations.strain_rate(grad_v))
    nu_t = equations.eddy_viscosity(cs_nodes, cfg.delta_filter, s_mag)
    return grad_prim, nu_t


def rhs_divergence(
    u: jax.Array,
    prim: tuple[jax.Array, ...],
    grad_prim: jax.Array,
    nu_t: jax.Array,
    cfg: HITConfig,
    ops: dict,
) -> jax.Array:
    """Stage 2 of the unfused RHS: -div(F_adv - F_visc) over the three
    directions (split-form volume, LLF + BR1-central surfaces)."""
    dg, gas = cfg.dg, cfg.gas
    d_matrix, inv_w_end = ops["D"], ops["inv_w_end"]
    rhs = None
    for d in range(3):
        # --- advective: split-form volume + LLF surface -------------------
        vol_adv = dgsem.flux_differencing(
            prim, equations.kennedy_gruber_flux, d_matrix, d
        )
        f_adv_nodes = equations.advective_flux(u, d)
        u_left, u_right = dgsem.neighbor_traces(u, d)
        f_star_adv = equations.lax_friedrichs_flux(u_left, u_right, d)
        # --- viscous: standard derivative volume + central surface --------
        f_visc = equations.viscous_flux(u, grad_prim, nu_t, gas, d)
        vol_visc = dgsem.deriv_along(f_visc, d_matrix, d)
        fv_left, fv_right = dgsem.neighbor_traces(f_visc, d)
        f_star_visc = 0.5 * (fv_left + fv_right)

        vol = vol_adv - vol_visc
        f_star = f_star_adv - f_star_visc
        f_nodes = f_adv_nodes - f_visc
        lo, hi = dgsem._face_slices(f_nodes, d)
        f_star_left = dgsem.left_faces(f_star, d)  # periodic wrap
        div_d = dgsem.surface_lift(vol, f_star - hi, f_star_left - lo, d, inv_w_end)
        div_d = div_d * dg.jac
        rhs = -div_d if rhs is None else rhs - div_d
    return rhs


def rhs_forcing(u: jax.Array, vel: jax.Array, cfg: HITConfig) -> jax.Array:
    """Stage 3 of the unfused RHS: Lundgren linear forcing with the
    proportional TKE controller (whole-box quadrature means)."""
    dg = cfg.dg
    mom = u[..., 1:4]
    mom_mean = dgsem.quadrature_mean(mom, dg)  # (..., 3)
    mom_fluct = mom - mom_mean[..., None, None, None, None, None, None, :]
    ke_density = 0.5 * jnp.sum(mom * vel, axis=-1, keepdims=True)
    k_now = dgsem.quadrature_mean(ke_density, dg)[..., 0]  # (...,)
    a_eff = cfg.forcing_a0 * jnp.clip(cfg.k_tke / jnp.maximum(k_now, 0.1 * cfg.k_tke), 0.0, 3.0)
    a_eff = a_eff[..., None, None, None, None, None, None]
    f_mom = a_eff[..., None] * mom_fluct
    f_e = jnp.sum(f_mom * vel, axis=-1, keepdims=True)
    return jnp.concatenate(
        [jnp.zeros_like(u[..., :1]), f_mom, f_e], axis=-1
    )


def navier_stokes_rhs(
    u: jax.Array, cs_nodes: jax.Array, cfg: HITConfig, ops: dict
) -> jax.Array:
    """-div(F_adv - F_visc) + forcing, the full semi-discrete RHS.

    Advective volume terms use *split-form* flux differencing with the
    Kennedy-Gruber kinetic-energy-preserving two-point flux — FLEXI's
    stabilization for underresolved turbulence (standard-form collocated
    DGSEM aliases and blows up on this test case within a few steps).
    Surface terms use local Lax-Friedrichs; viscous terms are BR1-style
    central.

    With `cfg.kernels_enabled` the whole evaluation is ONE fused Pallas
    launch (kernels/rhs.py: derivative -> fluxes -> eddy viscosity ->
    divergence + forcing with intermediates in VMEM); otherwise the staged
    jnp assembly below runs — it is the kernel's validated oracle
    (tests/test_kernel_parity.py).
    """
    if cfg.kernels_enabled:
        from ..kernels import ops as kops

        return kops.navier_stokes_rhs_fused(
            u, cs_nodes, ops["D"], ops["w"], inv_w_end=ops["inv_w_end"],
            jac=cfg.dg.jac, delta=cfg.delta_filter, mu=cfg.gas.mu,
            prandtl=cfg.prandtl, prandtl_turb=cfg.prandtl_turb,
            forcing_a0=cfg.forcing_a0, k_tke=cfg.k_tke, impl="kernel")

    rho, vel, p, temp = equations.conservative_to_primitive(u)
    e_spec = u[..., 4] / rho
    prim = (rho, vel, p, e_spec)
    q_prim = jnp.concatenate([vel, temp[..., None]], axis=-1)
    grad_prim, nu_t = rhs_gradients(q_prim, cs_nodes, cfg, ops)
    rhs = rhs_divergence(u, prim, grad_prim, nu_t, cfg, ops)
    return rhs + rhs_forcing(u, vel, cfg)


def rk_substep(u: jax.Array, cs_nodes: jax.Array, cfg: HITConfig, ops: dict) -> jax.Array:
    """One low-storage RK5(4) step of size cfg.dt."""
    dt = jnp.asarray(cfg.dt, dtype=u.dtype)
    du = jnp.zeros_like(u)
    for stage in range(5):
        # the cast keeps the carry in the rollout compute dtype: the jnp RHS
        # promotes a bf16 state to f32 (float32 operator matrices), while
        # the fused kernel already returns u.dtype — both are no-ops in the
        # default fp32 path.  RK constants go through float() so the weak
        # python scalar cannot re-promote a bf16 carry.
        rhs = navier_stokes_rhs(u, cs_nodes, cfg, ops).astype(u.dtype)
        du = float(_RK_A[stage]) * du + dt * rhs
        u = u + float(_RK_B[stage]) * du
    return u


@functools.partial(jax.jit, static_argnames=("cfg",))
def advance_rl_interval(u: jax.Array, cs_elem: jax.Array, cfg: HITConfig) -> jax.Array:
    """Advance the LES by Delta t_RL under fixed per-element C_s (one MDP
    transition).  This is the unit of work the paper distributes over MPI
    ranks; here it is one XLA program.

    With `cfg.precision == "bf16"` the state is advanced in bfloat16 for
    the whole interval (the mixed-precision rollout) and cast back to
    float32 at the boundary, so observations/reward/PPO stay float32."""
    ops = cfg.operators()
    cs_nodes = broadcast_cs(cs_elem, cfg)
    dtype = cfg.compute_dtype
    u = u.astype(dtype)
    cs_nodes = cs_nodes.astype(dtype)
    if dtype != jnp.float32:
        # cast the operator matrices to the compute dtype too, or every
        # D @ u / quadrature contraction re-promotes the carry to f32 and
        # demotes it back each RK stage (a state-sized round trip per
        # substep — the churn JAX002 guards against)
        ops = dict(ops, D=ops["D"].astype(dtype), w=ops["w"].astype(dtype))

    def body(u, _):
        return rk_substep(u, cs_nodes, cfg, ops), None

    u, _ = jax.lax.scan(body, u, None, length=cfg.n_substeps)
    return u.astype(jnp.float32)

"""Discontinuous-Galerkin spectral element (DGSEM) operators on a periodic
Cartesian mesh — the JAX port of FLEXI's core discretization (Krais et al.
2021), restricted to the homogeneous-isotropic-turbulence box the paper uses.

Layout convention for nodal state arrays:

    u.shape = (..., Kx, Ky, Kz, n, n, n, C)

with element axes at positions (-7, -6, -5), intra-element GLL node axes at
(-4, -3, -2) and the channel axis last.  `...` carries the environment batch;
all operators are batch-transparent and therefore `vmap`/`shard_map` friendly.

The per-direction derivative is a tiny (n x n) matrix contraction applied over
a huge batch of elements — the solver's dominant FLOP term.  The jnp path here
is the reference; `repro.kernels.ops.dg_derivative` provides the fused Pallas
TPU kernel with an identical contract (see kernels/dg_derivative.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import gll

# Element axes / node axes for direction d in {0,1,2}.
ELEM_AXIS = (-7, -6, -5)
NODE_AXIS = (-4, -3, -2)


@dataclasses.dataclass(frozen=True)
class DGParams:
    """Static (hashable) discretization parameters.

    All operator matrices are numpy constants closed over by jit — they never
    become traced values.
    """

    n_poly: int
    n_elem: int
    length: float = 2.0 * np.pi

    @property
    def n(self) -> int:
        return self.n_poly + 1

    @property
    def dx(self) -> float:
        return self.length / self.n_elem

    @property
    def jac(self) -> float:
        """d(xi)/dx: reference-to-physical scaling for derivatives."""
        return 2.0 / self.dx

    @property
    def n_dof_dir(self) -> int:
        return self.n_elem * self.n

    # --- cached numpy operators -------------------------------------------
    def nodes_weights(self) -> tuple[np.ndarray, np.ndarray]:
        return gll.gll_nodes_weights(self.n_poly)

    def deriv_matrix(self) -> np.ndarray:
        return gll.lagrange_derivative_matrix(self.n_poly)

    def interp_to_uniform(self) -> np.ndarray:
        x_gll, _ = self.nodes_weights()
        return gll.lagrange_interpolation_matrix(x_gll, gll.equispaced_nodes(self.n))

    def node_coords(self) -> np.ndarray:
        """Physical coordinates of every GLL node, shape (K, n) per direction."""
        x_gll, _ = self.nodes_weights()
        offsets = (np.arange(self.n_elem) + 0.5) * self.dx
        return offsets[:, None] + 0.5 * self.dx * x_gll[None, :]


def deriv_along(u: jax.Array, d_matrix: jax.Array, direction: int) -> jax.Array:
    """Apply the Lagrange derivative matrix along node axis `direction`.

    out[..., i, ...] = sum_m D[i, m] u[..., m, ...]   (reference coords)
    """
    axis = NODE_AXIS[direction] + u.ndim
    moved = jnp.moveaxis(u, axis, -1)
    out = moved @ d_matrix.T
    return jnp.moveaxis(out, -1, axis)


def _face_slices(u: jax.Array, direction: int) -> tuple[jax.Array, jax.Array]:
    """Trace values at the two faces of every element along `direction`.

    Returns (u_at_node0, u_at_nodeN) with the node axis removed.
    """
    axis = NODE_AXIS[direction] + u.ndim
    lo = jax.lax.index_in_dim(u, 0, axis, keepdims=False)
    hi = jax.lax.index_in_dim(u, u.shape[axis] - 1, axis, keepdims=False)
    return lo, hi


def neighbor_traces(u: jax.Array, direction: int) -> tuple[jax.Array, jax.Array]:
    """States meeting at the 'right' face of every element along `direction`.

    face f sits between element e (its node N trace -> `left`) and element
    e+1 (its node 0 trace -> `right`); periodic wrap via roll.
    """
    lo, hi = _face_slices(u, direction)
    elem_axis = ELEM_AXIS[direction] + lo.ndim + 1  # one axis was dropped
    right = jnp.roll(lo, shift=-1, axis=elem_axis)
    return hi, right


def surface_lift(
    du: jax.Array,
    flux_jump_right: jax.Array,
    flux_jump_left: jax.Array,
    direction: int,
    inv_w_end: tuple[float, float],
) -> jax.Array:
    """Add the strong-form DGSEM surface correction along `direction`.

    du_i += (delta_iN / w_N) * (F* - F)_right  -  (delta_i0 / w_0) * (F* - F)_left
    """
    axis = NODE_AXIS[direction] + du.ndim
    moved = jnp.moveaxis(du, axis, -1)  # (..., C, n) ordering after move
    inv_w0, inv_wn = inv_w_end
    moved = moved.at[..., -1].add(inv_wn * flux_jump_right)
    moved = moved.at[..., 0].add(-inv_w0 * flux_jump_left)
    return jnp.moveaxis(moved, -1, axis)


def dg_gradient(
    q: jax.Array,
    dg: DGParams,
    d_matrix: jax.Array,
    inv_w_end: tuple[float, float],
    vol_derivs: tuple[jax.Array, jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """BR1-style DG gradient of nodal field q (..., K,K,K, n,n,n, C).

    Uses central (arithmetic-mean) interface values.  Returns gradient with a
    new leading channel of size 3 appended at the end: (..., C, 3).
    `vol_derivs` optionally supplies the three reference-space volume
    derivatives (e.g. from the fused Pallas kernel kernels.ops.dg_derivative3).
    """
    grads = []
    for d in range(3):
        vol = deriv_along(q, d_matrix, d) if vol_derivs is None else vol_derivs[d]
        q_left, q_right = neighbor_traces(q, d)
        q_star_right = 0.5 * (q_left + q_right)  # face between e, e+1
        # jump contributions: at node N of e use face e|e+1, at node 0 of e
        # use face e-1|e  (roll back).
        elem_axis = ELEM_AXIS[d] + q_star_right.ndim + 1
        lo, hi = _face_slices(q, d)
        jump_right = q_star_right - hi
        q_star_left = jnp.roll(q_star_right, shift=1, axis=elem_axis)
        jump_left = q_star_left - lo
        g = surface_lift(vol, jump_right, jump_left, d, inv_w_end)
        grads.append(g * dg.jac)
    return jnp.stack(grads, axis=-1)


def flux_differencing(
    prim: tuple[jax.Array, ...],
    two_point_flux,
    d_matrix: jax.Array,
    direction: int,
) -> jax.Array:
    """Split-form volume integral:  out_i = sum_j 2 D_ij F#(u_i, u_j).

    `prim` is a tuple of nodal primitive arrays (last axis = channels for the
    velocity entry, none for scalars).  The pairwise states are formed along
    the node axis of `direction`; reduces to the standard derivative of the
    flux for F# = {F} on linear problems (SBP property).
    """
    def pairwise(q, is_vec):
        # absolute node-axis position: scalars have no trailing channel axis
        a = q.ndim + NODE_AXIS[direction] + (0 if is_vec else 1)
        moved = jnp.moveaxis(q, a, -2 if is_vec else -1)
        if is_vec:  # (..., m, C) -> (..., m_i, m_j, C)
            return moved[..., :, None, :], moved[..., None, :, :]
        return moved[..., :, None], moved[..., None, :]

    rho, vel, p, e = prim
    (rho_a, rho_b) = pairwise(rho, False)
    (vel_a, vel_b) = pairwise(vel, True)
    (p_a, p_b) = pairwise(p, False)
    (e_a, e_b) = pairwise(e, False)
    f_pair = two_point_flux((rho_a, vel_a, p_a, e_a), (rho_b, vel_b, p_b, e_b), direction)
    # contract the j axis with 2*D:  (..., m_i, m_j, C) x D[i, j] -> (..., m_i, C)
    out = 2.0 * jnp.einsum("ij,...ijc->...ic", d_matrix, f_pair)
    return jnp.moveaxis(out, -2, NODE_AXIS[direction] + out.ndim)


def dg_divergence(
    fluxes: tuple[jax.Array, jax.Array, jax.Array],
    fluxes_star: tuple[jax.Array, jax.Array, jax.Array],
    dg: DGParams,
    d_matrix: jax.Array,
    inv_w_end: tuple[float, float],
) -> jax.Array:
    """Strong-form DG divergence with prescribed interface fluxes.

    `fluxes[d]`       : nodal physical flux in direction d (..., n,n,n, C)
    `fluxes_star[d]`  : numerical flux on the face between e and e+1 along d,
                        shape like a trace (..., K,K,K, n,n, C) with the node
                        axis of direction d removed.
    Returns -div(F) in physical coordinates (the RHS convention).
    """
    out = None
    for d in range(3):
        vol = deriv_along(fluxes[d], d_matrix, d)
        lo, hi = _face_slices(fluxes[d], d)
        f_star_right = fluxes_star[d]
        elem_axis = ELEM_AXIS[d] + f_star_right.ndim + 1
        f_star_left = jnp.roll(f_star_right, shift=1, axis=elem_axis)
        jump_right = f_star_right - hi
        jump_left = f_star_left - lo
        div_d = surface_lift(vol, jump_right, jump_left, d, inv_w_end) * dg.jac
        out = div_d if out is None else out + div_d
    return -out


def quadrature_mean(q: jax.Array, dg: DGParams) -> jax.Array:
    """Volume average of nodal field q over the whole box (per batch entry).

    q: (..., K,K,K, n,n,n, C) -> (..., C)
    """
    _, w = dg.nodes_weights()
    w = jnp.asarray(w, dtype=q.dtype) * 0.5  # reference [-1,1] -> unit mass
    q = jnp.einsum("...xyzijkc,i,j,k->...c", q, w, w, w)
    return q / (dg.n_elem**3)

"""Discontinuous-Galerkin spectral element (DGSEM) operators on a Cartesian
mesh — the JAX port of FLEXI's core discretization (Krais et al. 2021),
with per-direction boundary conditions (periodic or prescribed-face).

Layout convention for nodal state arrays:

    u.shape = (..., Kx, Ky, Kz, n, n, n, C)

with element axes at positions (-7, -6, -5), intra-element GLL node axes at
(-4, -3, -2) and the channel axis last.  `...` carries the environment batch;
all operators are batch-transparent and therefore `vmap`/`shard_map` friendly.
Element counts (and element sizes) may differ per direction — operators that
scale to physical space accept a per-direction `jac`.

Boundary-condition abstraction and its layout contract
------------------------------------------------------
Face arrays are *right-face-indexed*: a trace/flux array for direction d has
the node axis of d removed, and entry e along the element axis of d holds the
face BETWEEN element e and element e+1.  Two helpers make the surface
exchange explicit about topology:

  * `set_face(arr, d, index, value)` overwrites one face slab (index -1 is
    the +L domain-boundary face in a right-face-indexed array; index 0 is
    the -0 boundary face in a LEFT-face-indexed array).
  * `left_faces(f_right, d, lo_value=None)` converts right-face-indexed to
    left-face-indexed (entry e = face on the LEFT of element e).  With
    `lo_value=None` the direction is periodic (the wrap is a `jnp.roll`);
    passing `lo_value` makes the direction non-periodic by overriding
    element 0's left face — whose rolled entry is the meaningless wrap —
    with the prescribed boundary flux/trace.

A non-periodic direction therefore costs exactly two `set_face` overrides on
top of the periodic path (one per wall), and the periodic path is unchanged
byte-for-byte.  `dg_gradient` / `dg_divergence` take an optional per-direction
`bc` tuple built on these helpers; `cfd/channel.py` assembles the full
no-slip/wall-model Navier-Stokes RHS from them.

The per-direction derivative is a tiny (n x n) matrix contraction applied over
a huge batch of elements — the solver's dominant FLOP term.  The jnp path here
is the reference; `repro.kernels.ops.dg_derivative` provides the fused Pallas
TPU kernel with an identical contract (see kernels/dg_derivative.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import gll

# Element axes / node axes for direction d in {0,1,2}.
ELEM_AXIS = (-7, -6, -5)
NODE_AXIS = (-4, -3, -2)


@dataclasses.dataclass(frozen=True)
class DGParams:
    """Static (hashable) discretization parameters.

    All operator matrices are numpy constants closed over by jit — they never
    become traced values.
    """

    n_poly: int
    n_elem: int
    length: float = 2.0 * np.pi

    @property
    def n(self) -> int:
        return self.n_poly + 1

    @property
    def dx(self) -> float:
        return self.length / self.n_elem

    @property
    def jac(self) -> float:
        """d(xi)/dx: reference-to-physical scaling for derivatives."""
        return 2.0 / self.dx

    @property
    def n_dof_dir(self) -> int:
        return self.n_elem * self.n

    # --- cached numpy operators -------------------------------------------
    def nodes_weights(self) -> tuple[np.ndarray, np.ndarray]:
        return gll.gll_nodes_weights(self.n_poly)

    def deriv_matrix(self) -> np.ndarray:
        return gll.lagrange_derivative_matrix(self.n_poly)

    def interp_to_uniform(self) -> np.ndarray:
        x_gll, _ = self.nodes_weights()
        return gll.lagrange_interpolation_matrix(x_gll, gll.equispaced_nodes(self.n))

    def node_coords(self) -> np.ndarray:
        """Physical coordinates of every GLL node, shape (K, n) per direction."""
        x_gll, _ = self.nodes_weights()
        offsets = (np.arange(self.n_elem) + 0.5) * self.dx
        return offsets[:, None] + 0.5 * self.dx * x_gll[None, :]


def deriv_along(u: jax.Array, d_matrix: jax.Array, direction: int) -> jax.Array:
    """Apply the Lagrange derivative matrix along node axis `direction`.

    out[..., i, ...] = sum_m D[i, m] u[..., m, ...]   (reference coords)
    """
    axis = NODE_AXIS[direction] + u.ndim
    moved = jnp.moveaxis(u, axis, -1)
    out = moved @ d_matrix.T
    return jnp.moveaxis(out, -1, axis)


def _face_slices(u: jax.Array, direction: int) -> tuple[jax.Array, jax.Array]:
    """Trace values at the two faces of every element along `direction`.

    Returns (u_at_node0, u_at_nodeN) with the node axis removed.
    """
    axis = NODE_AXIS[direction] + u.ndim
    lo = jax.lax.index_in_dim(u, 0, axis, keepdims=False)
    hi = jax.lax.index_in_dim(u, u.shape[axis] - 1, axis, keepdims=False)
    return lo, hi


def neighbor_traces(u: jax.Array, direction: int) -> tuple[jax.Array, jax.Array]:
    """States meeting at the 'right' face of every element along `direction`.

    face f sits between element e (its node N trace -> `left`) and element
    e+1 (its node 0 trace -> `right`); periodic wrap via roll.
    """
    lo, hi = _face_slices(u, direction)
    elem_axis = ELEM_AXIS[direction] + lo.ndim + 1  # one axis was dropped
    right = jnp.roll(lo, shift=-1, axis=elem_axis)
    return hi, right


def set_face(face_arr: jax.Array, direction: int, index: int,
             value: jax.Array) -> jax.Array:
    """Overwrite one domain-boundary face slab of a face-indexed array.

    `face_arr` has the node axis of `direction` removed (trace/flux layout);
    `value` has the element axis of `direction` removed as well (one face
    slab, broadcastable).  `index` is -1 for the +L face of a
    right-face-indexed array, 0 for the -0 face of a left-face-indexed one.
    """
    axis = ELEM_AXIS[direction] + face_arr.ndim + 1
    moved = jnp.moveaxis(face_arr, axis, 0)
    moved = moved.at[index].set(value)
    return jnp.moveaxis(moved, 0, axis)


def left_faces(f_right: jax.Array, direction: int,
               lo_value: jax.Array | None = None) -> jax.Array:
    """Right-face-indexed -> left-face-indexed along `direction`.

    Entry e of the result is the face on the LEFT of element e.  Periodic by
    default (element 0 wraps to the last face); a non-periodic direction
    passes `lo_value`, the prescribed boundary flux/trace at the -0 domain
    face, which overrides the meaningless wrapped entry.
    """
    axis = ELEM_AXIS[direction] + f_right.ndim + 1
    out = jnp.roll(f_right, shift=1, axis=axis)
    if lo_value is not None:
        out = set_face(out, direction, 0, lo_value)
    return out


def _per_direction_jac(dg: DGParams | None, jac) -> tuple[float, float, float]:
    """Resolve the reference-to-physical scaling for each direction."""
    if jac is None:
        if dg is None:
            raise ValueError("pass jac= (scalar or per-direction) when no "
                             "DGParams is given")
        return (dg.jac,) * 3
    if isinstance(jac, (tuple, list)):
        return tuple(jac)
    return (jac,) * 3


def surface_lift(
    du: jax.Array,
    flux_jump_right: jax.Array,
    flux_jump_left: jax.Array,
    direction: int,
    inv_w_end: tuple[float, float],
) -> jax.Array:
    """Add the strong-form DGSEM surface correction along `direction`.

    du_i += (delta_iN / w_N) * (F* - F)_right  -  (delta_i0 / w_0) * (F* - F)_left
    """
    axis = NODE_AXIS[direction] + du.ndim
    moved = jnp.moveaxis(du, axis, -1)  # (..., C, n) ordering after move
    inv_w0, inv_wn = inv_w_end
    moved = moved.at[..., -1].add(inv_wn * flux_jump_right)
    moved = moved.at[..., 0].add(-inv_w0 * flux_jump_left)
    return jnp.moveaxis(moved, -1, axis)


def dg_gradient(
    q: jax.Array,
    dg: DGParams | None,
    d_matrix: jax.Array,
    inv_w_end: tuple[float, float],
    vol_derivs: tuple[jax.Array, jax.Array, jax.Array] | None = None,
    *,
    jac: float | tuple[float, float, float] | None = None,
    bc: tuple | None = None,
) -> jax.Array:
    """BR1-style DG gradient of nodal field q (..., K,K,K, n,n,n, C).

    Uses central (arithmetic-mean) interface values.  Returns gradient with a
    new leading channel of size 3 appended at the end: (..., C, 3).
    `vol_derivs` optionally supplies the three reference-space volume
    derivatives (e.g. from the fused Pallas kernel kernels.ops.dg_derivative3).

    `jac` overrides `dg.jac` (scalar or per-direction) for anisotropic
    meshes.  `bc` is None (fully periodic) or a 3-tuple whose entry d is
    None (periodic along d) or a pair `(q_lo, q_hi)` of prescribed boundary
    FACE states (one face slab each, see module docstring); the prescribed
    state replaces the central average at the two domain faces — a weak
    Dirichlet trace for the gradient.
    """
    jacs = _per_direction_jac(dg, jac)
    grads = []
    for d in range(3):
        vol = deriv_along(q, d_matrix, d) if vol_derivs is None else vol_derivs[d]
        q_left, q_right = neighbor_traces(q, d)
        q_star_right = 0.5 * (q_left + q_right)  # face between e, e+1
        # jump contributions: at node N of e use face e|e+1, at node 0 of e
        # use face e-1|e  (roll back; non-periodic overrides the wall faces).
        lo, hi = _face_slices(q, d)
        bc_d = bc[d] if bc is not None else None
        if bc_d is not None:
            q_star_right = set_face(q_star_right, d, -1, bc_d[1])
        q_star_left = left_faces(q_star_right, d,
                                 lo_value=bc_d[0] if bc_d is not None else None)
        jump_right = q_star_right - hi
        jump_left = q_star_left - lo
        g = surface_lift(vol, jump_right, jump_left, d, inv_w_end)
        grads.append(g * jacs[d])
    return jnp.stack(grads, axis=-1)


def flux_differencing(
    prim: tuple[jax.Array, ...],
    two_point_flux,
    d_matrix: jax.Array,
    direction: int,
) -> jax.Array:
    """Split-form volume integral:  out_i = sum_j 2 D_ij F#(u_i, u_j).

    `prim` is a tuple of nodal primitive arrays (last axis = channels for the
    velocity entry, none for scalars).  The pairwise states are formed along
    the node axis of `direction`; reduces to the standard derivative of the
    flux for F# = {F} on linear problems (SBP property).
    """
    def pairwise(q, is_vec):
        # absolute node-axis position: scalars have no trailing channel axis
        a = q.ndim + NODE_AXIS[direction] + (0 if is_vec else 1)
        moved = jnp.moveaxis(q, a, -2 if is_vec else -1)
        if is_vec:  # (..., m, C) -> (..., m_i, m_j, C)
            return moved[..., :, None, :], moved[..., None, :, :]
        return moved[..., :, None], moved[..., None, :]

    rho, vel, p, e = prim
    (rho_a, rho_b) = pairwise(rho, False)
    (vel_a, vel_b) = pairwise(vel, True)
    (p_a, p_b) = pairwise(p, False)
    (e_a, e_b) = pairwise(e, False)
    f_pair = two_point_flux((rho_a, vel_a, p_a, e_a), (rho_b, vel_b, p_b, e_b), direction)
    # contract the j axis with 2*D:  (..., m_i, m_j, C) x D[i, j] -> (..., m_i, C)
    out = 2.0 * jnp.einsum("ij,...ijc->...ic", d_matrix, f_pair)
    return jnp.moveaxis(out, -2, NODE_AXIS[direction] + out.ndim)


def dg_divergence(
    fluxes: tuple[jax.Array, jax.Array, jax.Array],
    fluxes_star: tuple[jax.Array, jax.Array, jax.Array],
    dg: DGParams | None,
    d_matrix: jax.Array,
    inv_w_end: tuple[float, float],
    *,
    jac: float | tuple[float, float, float] | None = None,
    bc: tuple | None = None,
) -> jax.Array:
    """Strong-form DG divergence with prescribed interface fluxes.

    `fluxes[d]`       : nodal physical flux in direction d (..., n,n,n, C)
    `fluxes_star[d]`  : numerical flux on the face between e and e+1 along d,
                        shape like a trace (..., K,K,K, n,n, C) with the node
                        axis of direction d removed.
    `jac` / `bc` as in `dg_gradient` — `bc[d]` is None or `(f_lo, f_hi)`
    prescribed boundary NUMERICAL fluxes replacing the wrapped faces.
    Returns -div(F) in physical coordinates (the RHS convention).
    """
    jacs = _per_direction_jac(dg, jac)
    out = None
    for d in range(3):
        vol = deriv_along(fluxes[d], d_matrix, d)
        lo, hi = _face_slices(fluxes[d], d)
        f_star_right = fluxes_star[d]
        bc_d = bc[d] if bc is not None else None
        if bc_d is not None:
            f_star_right = set_face(f_star_right, d, -1, bc_d[1])
        f_star_left = left_faces(f_star_right, d,
                                 lo_value=bc_d[0] if bc_d is not None else None)
        jump_right = f_star_right - hi
        jump_left = f_star_left - lo
        div_d = surface_lift(vol, jump_right, jump_left, d, inv_w_end) * jacs[d]
        out = div_d if out is None else out + div_d
    return -out


def quadrature_mean(q: jax.Array, dg: DGParams) -> jax.Array:
    """Volume average of nodal field q over the whole box (per batch entry).

    q: (..., Kx,Ky,Kz, n,n,n, C) -> (..., C).  The element count is read off
    the array, so anisotropic (Kx != Ky != Kz) meshes average correctly.
    """
    _, w = dg.nodes_weights()
    w = jnp.asarray(w, dtype=q.dtype) * 0.5  # reference [-1,1] -> unit mass
    n_elem_total = q.shape[-7] * q.shape[-6] * q.shape[-5]
    q = jnp.einsum("...xyzijkc,i,j,k->...c", q, w, w, w)
    return q / n_elem_total

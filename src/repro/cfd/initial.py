"""Initial-state generation for the HIT environment.

The paper draws initial LES states from filtered DNS snapshots staged on a
RAM disk.  Offline we have no DNS, so we synthesize statistically equivalent
states: divergence-free Gaussian velocity fields with the von Karman-Pao
target spectrum (Rogallo-style spectral sampling), evaluated exactly at the
GLL nodes via band-limited Fourier interpolation.  The resulting bank of
states is device-resident — the TPU-native version of the RAM-disk trick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import gll, spectra
from .equations import primitive_to_conservative
from .solver import HITConfig


def _solenoidal_spectral_field(key: jax.Array, n_grid: int, e_target: jax.Array) -> jax.Array:
    """Random divergence-free velocity field on a uniform n^3 grid with shell
    spectrum e_target (length n_shells). Returns (n, n, n, 3) real field."""
    shells, n_shells, weight = spectra._shell_bins(n_grid)
    noise = jax.random.normal(key, (n_grid, n_grid, n_grid, 3), dtype=jnp.float32)
    vhat = jnp.fft.rfftn(noise, axes=(0, 1, 2))

    k1 = np.fft.fftfreq(n_grid, d=1.0 / n_grid)
    kr = np.fft.rfftfreq(n_grid, d=1.0 / n_grid)
    kx, ky, kz = np.meshgrid(k1, k1, kr, indexing="ij")  # repro-lint: disable=AST001 -- static wavenumber grid (n_grid is static)
    k_vec = jnp.asarray(np.stack([kx, ky, kz], axis=-1), dtype=jnp.float32)  # repro-lint: disable=AST001 -- static wavenumber grid (n_grid is static)
    k_sq = jnp.sum(k_vec**2, axis=-1, keepdims=True)
    k_sq = jnp.where(k_sq == 0, 1.0, k_sq)
    # Zero the Nyquist planes: the Helmholtz projector is sign-ambiguous there
    # and irfftn's Hermitian symmetrization would reintroduce divergence.
    nyq = n_grid // 2
    mask = (np.abs(kx) < nyq) & (np.abs(ky) < nyq) & (kz < nyq)  # repro-lint: disable=AST001 -- static Nyquist mask (n_grid is static)
    vhat = vhat * jnp.asarray(mask[..., None], dtype=vhat.dtype)
    # Helmholtz projection: remove the compressive component.
    proj = vhat - k_vec * jnp.sum(k_vec * vhat, axis=-1, keepdims=True) / k_sq
    # Current shell energies -> rescale to target.
    e_density = 0.5 * jnp.sum(jnp.abs(proj) ** 2, axis=-1) * jnp.asarray(weight) / (n_grid**6)
    e_now = jax.ops.segment_sum(e_density.reshape(-1), jnp.asarray(shells.reshape(-1)),
                                num_segments=n_shells)
    scale = jnp.sqrt(e_target / jnp.maximum(e_now, 1e-30))
    scale = jnp.where(e_target > 0, scale, 0.0)
    proj = proj * scale[jnp.asarray(shells)][..., None]
    vel = jnp.fft.irfftn(proj, s=(n_grid,) * 3, axes=(0, 1, 2))
    return vel


@functools.lru_cache(maxsize=16)
def _fourier_to_gll_matrix(n_grid: int, n_elem: int, n_poly: int, length: float) -> np.ndarray:
    """Complex (K*n, n_grid) matrix evaluating the uniform-grid Fourier series
    at the global GLL coordinates of one direction."""
    from .dgsem import DGParams

    dg = DGParams(n_poly, n_elem, length)
    x_gll = dg.node_coords().reshape(-1)  # (K*n,)
    return gll.fourier_eval_matrix(n_grid, x_gll, length)


def uniform_to_gll(field: jax.Array, cfg: HITConfig) -> jax.Array:
    """Band-limited interpolation (..., N,N,N, C) uniform -> GLL nodal layout
    (..., K,K,K, n,n,n, C)."""
    n_grid = field.shape[-2]
    mat = jnp.asarray(
        _fourier_to_gll_matrix(n_grid, cfg.n_elem, cfg.n_poly, cfg.length),
        dtype=jnp.complex64,
    )
    fhat = jnp.fft.fftn(field, axes=(-4, -3, -2))
    for axis_offset in range(3):
        axis = fhat.ndim - 4 + axis_offset
        fhat = jnp.moveaxis(jnp.moveaxis(fhat, axis, -1) @ mat.T, -1, axis)
    out = jnp.real(fhat)
    # split each global axis (K*n) into (K, n), then order (...,K,K,K,n,n,n,C)
    batch = out.shape[: out.ndim - 4]
    k, n, c = cfg.n_elem, cfg.n_poly + 1, out.shape[-1]
    out = out.reshape(batch + (k, n, k, n, k, n, c))
    nd = out.ndim
    perm = list(range(nd - 7)) + [nd - 7, nd - 5, nd - 3, nd - 6, nd - 4, nd - 2, nd - 1]
    return jnp.transpose(out, perm)


def sample_initial_state(key: jax.Array, cfg: HITConfig) -> jax.Array:
    """One random conservative initial state (K,K,K,n,n,n,5)."""
    n_grid = cfg.dg.n_dof_dir
    e_target = jnp.asarray(spectra.reference_spectrum(cfg), dtype=jnp.float32)
    vel_uniform = _solenoidal_spectral_field(key, n_grid, e_target)
    vel = uniform_to_gll(vel_uniform[..., None, :].reshape(n_grid, n_grid, n_grid, 3), cfg)
    rho = jnp.full(vel.shape[:-1], cfg.rho0, dtype=vel.dtype)
    p = jnp.full(vel.shape[:-1], cfg.p0, dtype=vel.dtype)
    return primitive_to_conservative(rho, vel, p)


def make_state_bank(key: jax.Array, cfg: HITConfig, n_states: int) -> jax.Array:
    """Bank of initial states (n_states, K,K,K,n,n,n,5); one is conventionally
    held out as the unseen test state (index -1, as in the paper)."""
    keys = jax.random.split(key, n_states)
    return jax.vmap(lambda k: sample_initial_state(k, cfg))(keys)

"""Clip-PPO (Schulman et al. 2017) with GAE — the paper's RL algorithm.

Synchronous on-policy training exactly as in the paper (Sec. 5.3): sample a
batch of complete episodes with the current policy, then run `n_epochs`
gradient-ascent passes over the collected trajectories.  Hyperparameters
default to the paper's: gamma=0.995, lr=1e-4, Adam, 5 epochs, clip 0.2,
entropy coefficient 0.

Trajectories are laid out time-major:  (T, B, ...) with B the environment
batch — B is the axis that shards over the (pod, data) mesh axes (the
paper's "number of parallel FLEXI instances").
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .. import optim
from . import policy as policy_lib


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    gamma: float = 0.995          # paper Sec. 5.3
    lam: float = 0.95             # GAE lambda (TF-Agents default)
    clip: float = 0.2             # paper Sec. 5.3
    entropy_coef: float = 0.0     # paper Sec. 5.3
    value_coef: float = 0.5
    n_epochs: int = 5             # paper Sec. 5.3
    lr: float = 1e-4              # paper Sec. 5.3
    grad_clip: float | None = 1.0
    normalize_advantages: bool = True

    @property
    def adam(self) -> optim.AdamConfig:
        return optim.AdamConfig(lr=self.lr, grad_clip=self.grad_clip)


class Trajectory(NamedTuple):
    """Time-major rollout batch.  obs includes s_0..s_{T-1}; bootstrap value
    closes the episode (envs here terminate at fixed T, so last_value matters
    only for truncation handling; paper episodes end at t_end -> treat as
    terminal: done[-1] = True)."""

    obs: jax.Array        # (T, B, E, n, n, n, C)
    actions: jax.Array    # (T, B, E)
    log_probs: jax.Array  # (T, B)
    rewards: jax.Array    # (T, B)
    dones: jax.Array      # (T, B) bool, True where episode TERMINATES at t
    values: jax.Array     # (T, B) V(s_t) under the behavior policy
    last_value: jax.Array  # (B,) V(s_T)


def gae(traj: Trajectory, gamma: float, lam: float) -> tuple[jax.Array, jax.Array]:
    """Generalized advantage estimation; returns (advantages, returns), (T, B).

    delta_t = r_{t+1} + gamma V(s_{t+1}) (1-done) - V(s_t)
    A_t     = delta_t + gamma lam (1-done) A_{t+1}
    """
    not_done = 1.0 - traj.dones.astype(jnp.float32)
    next_values = jnp.concatenate(
        [traj.values[1:], traj.last_value[None]], axis=0
    )
    deltas = traj.rewards + gamma * next_values * not_done - traj.values

    def back(carry, x):
        delta, nd = x
        adv = delta + gamma * lam * nd * carry
        return adv, adv

    _, advs = jax.lax.scan(back, jnp.zeros_like(deltas[-1]), (deltas, not_done),
                           reverse=True)
    returns = advs + traj.values
    return advs, returns


def flatten_batch(traj: Trajectory, advantages: jax.Array,
                  returns: jax.Array, *, normalize: bool
                  ) -> tuple[jax.Array, ...]:
    """Flatten a time-major batch to the (T*B) minibatch tensors
    (obs, actions, log_probs, advantages, returns), optionally normalizing
    the advantages — shared by the single-scenario epoch below and the
    fleet's per-scenario joint update (fleet/multitask.py), so PPO
    preprocessing has one source of truth."""
    flat = jax.tree.map(
        lambda x: x.reshape((-1,) + x.shape[2:]),
        (traj.obs, traj.actions, traj.log_probs, advantages, returns),
    )
    obs_f, act_f, lp_f, adv_f, ret_f = flat
    if normalize:
        adv_f = (adv_f - jnp.mean(adv_f)) / (jnp.std(adv_f) + 1e-8)
    return obs_f, act_f, lp_f, adv_f, ret_f


def ppo_loss(
    params: dict,
    cfg: PPOConfig,
    pcfg: policy_lib.PolicyConfig | None,
    obs: jax.Array,
    actions: jax.Array,
    old_log_probs: jax.Array,
    advantages: jax.Array,
    returns: jax.Array,
    *,
    policy: policy_lib.PolicyFns | None = None,
) -> tuple[jax.Array, dict]:
    """Clipped surrogate + value loss + entropy bonus on a flat minibatch.

    `policy` optionally substitutes the policy callable bundle (the
    multi-scenario heads); left None it is bound from `pcfg`, which keeps
    the loss graph bit-identical to the pre-adapter path.
    """
    pol = policy if policy is not None else policy_lib.policy_fns(pcfg)
    mean, std = pol.dist(params, obs)
    new_log_probs = policy_lib.log_prob(mean, std, actions)
    ratio = jnp.exp(new_log_probs - old_log_probs)
    clipped = jnp.clip(ratio, 1.0 - cfg.clip, 1.0 + cfg.clip)
    surrogate = -jnp.mean(jnp.minimum(ratio * advantages, clipped * advantages))

    values = pol.value(params, obs)
    value_loss = 0.5 * jnp.mean((values - returns) ** 2)

    ent = jnp.mean(policy_lib.entropy(std))
    loss = surrogate + cfg.value_coef * value_loss - cfg.entropy_coef * ent
    stats = {
        "loss": loss,
        "surrogate": surrogate,
        "value_loss": value_loss,
        "entropy": ent,
        "approx_kl": jnp.mean(old_log_probs - new_log_probs),
        "clip_frac": jnp.mean((jnp.abs(ratio - 1.0) > cfg.clip).astype(jnp.float32)),
    }
    return loss, stats


def update_epoch(
    params: dict,
    opt_state: optim.adam.AdamState,
    cfg: PPOConfig,
    pcfg: policy_lib.PolicyConfig | None,
    traj: Trajectory,
    advantages: jax.Array,
    returns: jax.Array,
    *,
    policy: policy_lib.PolicyFns | None = None,
) -> tuple[dict, optim.adam.AdamState, dict]:
    """One full-batch gradient step over the flattened (T*B) experience.

    The paper trains full-batch for n_epochs (TF-Agents PPO default).  The
    (T*B) token axis is data-sharded; the psum of the gradient happens inside
    pjit via the sharded mean.
    """
    obs_f, act_f, lp_f, adv_f, ret_f = flatten_batch(
        traj, advantages, returns, normalize=cfg.normalize_advantages)

    (_, stats), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        params, cfg, pcfg, obs_f, act_f, lp_f, adv_f, ret_f, policy=policy
    )
    params, opt_state = optim.adam_update(cfg.adam, params, grads, opt_state)
    stats["grad_norm"] = optim.global_norm(grads)
    return params, opt_state, stats


def update(
    params: dict,
    opt_state: optim.adam.AdamState,
    cfg: PPOConfig,
    pcfg: policy_lib.PolicyConfig | None,
    traj: Trajectory,
    *,
    policy: policy_lib.PolicyFns | None = None,
) -> tuple[dict, optim.adam.AdamState, dict]:
    """Full PPO update: GAE once, then n_epochs gradient steps (lax.scan)."""
    advantages, returns = gae(traj, cfg.gamma, cfg.lam)

    def epoch(carry, _):
        params, opt_state = carry
        params, opt_state, stats = update_epoch(
            params, opt_state, cfg, pcfg, traj, advantages, returns,
            policy=policy
        )
        return (params, opt_state), stats

    (params, opt_state), stats_seq = jax.lax.scan(
        epoch, (params, opt_state), None, length=cfg.n_epochs
    )
    stats = jax.tree.map(lambda s: s[-1], stats_seq)
    stats["mean_return"] = jnp.mean(jnp.sum(traj.rewards, axis=0))
    return params, opt_state, stats

"""Versioned, atomic, integrity-checked checkpoints (no orbax offline).

Layout:  <dir>/step_<k>/
            manifest.json   {step, keys, shapes, dtypes, sha256, user_meta}
            <idx>.npy       one file per pytree leaf (host numpy)

Writes are atomic (tmp dir + fsync + rename), restores verify content hashes
— a half-written checkpoint after a node failure is detected and skipped, and
`latest_step` only ever returns complete checkpoints.  Restore is
template-based (caller supplies an abstract pytree with the same structure),
which is what lets `elastic.py` re-device_put onto a *different* mesh.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_paths(tree: Any) -> list[str]:
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(tree)[0]) if jax.tree.leaves(tree) else ((), None)
    return [jax.tree_util.keystr(p) for p in paths]


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save(directory: str, step: int, tree: Any, *, meta: dict | None = None,
         keep: int = 3) -> str:
    """Atomically write checkpoint for `step`; prune to the newest `keep`."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree.leaves(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    manifest = {
        "step": int(step),
        "keys": _leaf_paths(tree),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
        "sha256": [_sha256(a) for a in host],
        "meta": meta or {},
    }
    for i, a in enumerate(host):
        np.save(os.path.join(tmp, f"{i}.npy"), a)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic on POSIX

    # prune old complete checkpoints
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
    return final


def all_steps(directory: str) -> list[int]:
    """Steps with a complete (manifest present) checkpoint."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


class IntegrityError(RuntimeError):
    pass


def restore_arrays(directory: str, step: int, *, verify: bool = True
                   ) -> tuple[list[np.ndarray], dict]:
    """Load host arrays + manifest for `step`; verifies sha256 of every leaf."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    arrays = []
    for i, (shape, dtype, digest) in enumerate(
        zip(manifest["shapes"], manifest["dtypes"], manifest["sha256"])
    ):
        a = np.load(os.path.join(path, f"{i}.npy"))
        if list(a.shape) != shape or str(a.dtype) != dtype:
            raise IntegrityError(f"leaf {i}: shape/dtype mismatch in {path}")
        if verify and _sha256(a) != digest:
            raise IntegrityError(f"leaf {i}: content hash mismatch in {path}")
        arrays.append(a)
    return arrays, manifest


def restore(directory: str, step: int, template: Any, *, verify: bool = True,
            shardings: Any = None) -> tuple[Any, dict]:
    """Rebuild the pytree of `template`'s structure from checkpoint `step`.

    `shardings`: optional pytree (matching template) of jax.sharding.Sharding
    to place leaves directly onto a (possibly different) mesh — the elastic
    restart path.
    """
    arrays, manifest = restore_arrays(directory, step, verify=verify)
    tdef = jax.tree.structure(template)
    if tdef.num_leaves != len(arrays):
        raise IntegrityError(
            f"template has {tdef.num_leaves} leaves, checkpoint {len(arrays)}")
    if shardings is not None:
        shard_list = jax.tree.leaves(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_list)]
    else:
        arrays = [jax.numpy.asarray(a) for a in arrays]
    return jax.tree.unflatten(tdef, arrays), manifest

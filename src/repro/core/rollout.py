"""Synchronous sharded rollout — the TPU-native replacement for Relexi's
SmartSim launch/poll loop (paper Algorithm 1, lines 4-13).

Where the paper starts `n_envs` MPI jobs and ping-pongs state/action tuples
through a Redis database, here the environment batch IS one array program:
the batch axis shards over the (pod, data) mesh axes, element space of each
environment optionally shards over `model`, and one `lax.scan` over the
episode replaces launch + polling — synchronization becomes the data
dependency between scan iterations.  "Launch overhead" is a single XLA
dispatch (benchmarks/launch_overhead.py quantifies this against the paper's
Sec. 3.3 numbers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..cfd import env as env_lib
from ..cfd.solver import HITConfig
from . import policy as policy_lib
from .ppo import Trajectory


def rollout(
    params: dict,
    pcfg: policy_lib.PolicyConfig,
    env_cfg: HITConfig,
    e_dns: jax.Array,
    u0: jax.Array,
    key: jax.Array,
    *,
    deterministic: bool = False,
) -> Trajectory:
    """Roll a batch of environments for one full episode (T = n_actions).

    u0: (B, K,K,K, n,n,n, 5) initial conservative states.
    Returns a time-major Trajectory (T, B, ...).
    """
    n_steps = env_cfg.n_actions
    batch = u0.shape[0]
    state0 = env_lib.EnvState(
        u=u0, t_step=jnp.zeros((batch,), jnp.int32)
    )
    step_keys = jax.random.split(key, n_steps)

    def step_fn(state: env_lib.EnvState, key_t: jax.Array):
        obs = env_lib.observe(state.u, env_cfg)
        if deterministic:
            action = policy_lib.actor_mean(params, pcfg, obs)
            mean, std = policy_lib.distribution(params, pcfg, obs)
            logp = policy_lib.log_prob(mean, std, action)
        else:
            action, logp = policy_lib.sample_action(key_t, params, pcfg, obs)
        val = policy_lib.value(params, pcfg, obs)
        res = env_lib.step(state, action, env_cfg, e_dns)
        out = (obs, action, logp, res.reward, res.done, val)
        return res.state, out

    final_state, (obs, actions, log_probs, rewards, dones, values) = jax.lax.scan(
        step_fn, state0, step_keys
    )
    last_obs = env_lib.observe(final_state.u, env_cfg)
    last_value = policy_lib.value(params, pcfg, last_obs)
    return Trajectory(
        obs=obs,
        actions=actions,
        log_probs=log_probs,
        rewards=rewards,
        dones=dones,
        values=values,
        last_value=last_value,
    )


def episode_return(traj: Trajectory) -> jax.Array:
    """Undiscounted per-environment episode return (B,)."""
    return jnp.sum(traj.rewards, axis=0)


def normalized_return(traj: Trajectory) -> jax.Array:
    """Return normalized by the maximum achievable (+1 per step), as Fig. 5."""
    return episode_return(traj) / traj.rewards.shape[0]

"""Synchronous sharded rollout — the TPU-native replacement for Relexi's
SmartSim launch/poll loop (paper Algorithm 1, lines 4-13).

Where the paper starts `n_envs` MPI jobs and ping-pongs state/action tuples
through a Redis database, here the environment batch IS one array program:
the batch axis shards over the (pod, data) mesh axes, element space of each
environment optionally shards over `model`, and one `lax.scan` over the
episode replaces launch + polling — synchronization becomes the data
dependency between scan iterations.  "Launch overhead" is a single XLA
dispatch (benchmarks/launch_overhead.py quantifies this against the paper's
Sec. 3.3 numbers).

The scan is generic over any registered `Env` (envs/base.py): the env is a
static value closed over by jit, and `observe`/`step` are pure, so the same
function lowers the HIT-LES fleet and the 1-D Burgers fleet alike.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..envs.base import Env, EnvState
from . import policy as policy_lib
from .ppo import Trajectory


def rollout(
    params: dict,
    pcfg: policy_lib.PolicyConfig | None,
    env: Env,
    u0: jax.Array,
    key: jax.Array,
    *,
    deterministic: bool = False,
    policy: policy_lib.PolicyFns | None = None,
) -> Trajectory:
    """Roll a batch of environments for one full episode (T = env.n_actions).

    u0: (B, *state_shape) initial solver states (bank rows).
    Returns a time-major Trajectory (T, B, ...).

    `policy` optionally substitutes the whole policy callable bundle
    (e.g. a multi-scenario head from `fleet/multitask.py`); left None, the
    default single-scenario policy is bound from `pcfg`.

    The per-step action noise is pre-drawn OUTSIDE the scan (one
    `normal(key_t, (B,) + action_shape)` per step key — the same stream
    `PolicyFns.sample` would draw inside) and consumed as scan data.  This
    keeps the scan body structurally identical to the fleet's super-batch
    program (`fleet/superbatch.py`), whose padded batch must reproduce
    this path bit-for-bit on the real rows: drawing inside vs. feeding as
    data changes XLA's fusion (FMA) choices at the ulp level, so both
    paths draw the same way.
    """
    pol = policy if policy is not None else policy_lib.policy_fns(pcfg)
    n_steps = env.n_actions
    batch = u0.shape[0]
    state0 = EnvState(u=u0, t_step=jnp.zeros((batch,), jnp.int32))
    step_keys = jax.random.split(key, n_steps)
    noise = jax.vmap(
        lambda kk: jax.random.normal(kk, (batch,) + env.action_spec.shape)
    )(step_keys)

    def step_fn(state: EnvState, noise_t: jax.Array):
        obs = env.observe(state)
        if deterministic:
            action = pol.mean(params, obs)
            mean, std = pol.dist(params, obs)
            logp = policy_lib.log_prob(mean, std, action)
        else:
            mean, std = pol.dist(params, obs)
            action = mean + std * noise_t
            logp = policy_lib.log_prob(mean, std, action)
        val = pol.value(params, obs)
        res = env.step(state, action)
        out = (obs, action, logp, res.reward, res.done, val)
        return res.state, out

    final_state, (obs, actions, log_probs, rewards, dones, values) = jax.lax.scan(
        step_fn, state0, noise
    )
    last_obs = env.observe(final_state)
    last_value = pol.value(params, last_obs)
    return Trajectory(
        obs=obs,
        actions=actions,
        log_probs=log_probs,
        rewards=rewards,
        dones=dones,
        values=values,
        last_value=last_value,
    )


def episode_return(traj: Trajectory) -> jax.Array:
    """Undiscounted per-environment episode return (B,)."""
    return jnp.sum(traj.rewards, axis=0)


def normalized_return(traj: Trajectory) -> jax.Array:
    """Return normalized by the maximum achievable (+1 per step), as Fig. 5."""
    return episode_return(traj) / traj.rewards.shape[0]


def constant_action_return(env: Env, u0: jax.Array, value: float) -> float:
    """Normalized episode return of a constant-action policy on initial
    states u0 (B, *state_shape) — the paper's static baselines (Fig. 5
    bottom: Smagorinsky C_s=0.17, implicit LES C_s=0), for any Env."""
    state = EnvState(u=u0, t_step=jnp.zeros((u0.shape[0],), jnp.int32))
    action = jnp.full((u0.shape[0],) + env.action_spec.shape, value,
                      jnp.float32)
    step = jax.jit(env.step)
    total = 0.0
    for _ in range(env.n_actions):
        res = step(state, action)
        state = res.state
        total += float(jnp.mean(res.reward))
    return total / env.n_actions

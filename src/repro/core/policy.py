"""The paper's policy network (Table 2), generalized over env specs.

Input : per-element nodal observations (..., E, *spatial, C) — E = K^3 and
        3-D spatial for the HIT scenario, E = K and 1-D for Burgers.  C is
        the length of the env's DECLARED channel tuple
        (`ObsSpec.channel_specs`), never a hard-coded count: the trunk's
        input width follows the declaration (3 velocity channels for HIT,
        1 for Burgers, 4 velocity+wall-pressure for `channel_wm_p`), and
        each channel's declared `gain` is applied at the trunk input
        (`PolicyConfig.in_gains`) to re-balance channels whose O(1)
        normalization still leaves them small/large next to their siblings.
        All-unity gains compile to the identity — the pre-refactor graph.
Output: Gaussian policy over the per-element bounded scalar action,
        mean = low + (high-low) * sigmoid(conv(x)), state-independent
        learnable log-std (TF-Agents' default for continuous PPO).

The heads are built from the environment's declarative `ObsSpec` /
`ActionSpec` (`PolicyConfig.from_specs`) — nothing here knows which solver
produced the observations.  For the paper's N=5 HIT case (n=6, 3-D, the
3-channel velocity declaration) the stack reproduces Table 2 exactly
(3,293 parameters):

    Conv3D k3 f8 zero-pad -> 6^3 x 8   ReLU
    Conv3D k3 f8 no-pad   -> 4^3 x 8   ReLU
    Conv3D k3 f4 no-pad   -> 2^3 x 4   ReLU
    Conv3D k2 f1 no-pad   -> 1^3 x 1
    Scale  y = cs_max * sigmoid(x)

For other n the same pattern generalizes: one zero-padded k3 layer, k3
valid layers (filters 8, then 4) until the spatial size reaches 2, and a
final k2 valid layer to 1.  For 1-D envs the identical plan runs with
Conv1D kernels.

The critic is an identical (separately parameterized) trunk producing a
per-element scalar, averaged over elements — the state value.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    n_nodes: int = 6          # GLL nodes per direction = N+1
    channels: int = 3         # observation channels (trunk input width)
    cs_max: float = 0.5       # action upper bound (Table-2 name kept)
    log_std_init: float = -1.6  # std ~ 0.2 in sigmoid-space
    n_dims: int = 3           # spatial rank of per-element obs (3-D HIT, 1-D Burgers)
    act_low: float = 0.0      # action lower bound
    # Per-channel input gains from the env's declared ChannelSpec.gain,
    # applied as obs * in_gains before the first conv.  None (or all 1.0)
    # skips the multiply entirely, keeping legacy envs bit-identical.
    in_gains: tuple[float, ...] | None = None

    def __post_init__(self):
        if self.in_gains is not None and len(self.in_gains) != self.channels:
            raise ValueError(f"{len(self.in_gains)} input gains declared "
                             f"for {self.channels} channels")

    @classmethod
    def from_specs(cls, obs_spec, action_spec, *,
                   log_std_init: float = -1.6) -> "PolicyConfig":
        """Build the head configuration from an env's declarative specs:
        trunk input width = the declared channel count, input gains = the
        declared per-channel gains."""
        spatial = tuple(obs_spec.spatial)
        if len(set(spatial)) != 1:
            raise ValueError(f"anisotropic per-element grids unsupported: {spatial}")
        gains = tuple(getattr(obs_spec, "channel_gains", ()) or ())
        return cls(n_nodes=spatial[0], channels=obs_spec.channels,
                   cs_max=action_spec.high, act_low=action_spec.low,
                   n_dims=len(spatial), log_std_init=log_std_init,
                   in_gains=gains or None)

    @property
    def active_gains(self) -> tuple[float, ...] | None:
        """The input-gain vector, or None when it would be the identity
        (lengths are checked against `channels` at construction)."""
        if self.in_gains and any(g != 1.0 for g in self.in_gains):
            return self.in_gains
        return None


def _conv_plan(n: int) -> list[tuple[int, int, str]]:
    """[(kernel, filters, padding)] reducing spatial size n -> 1."""
    plan: list[tuple[int, int, str]] = [(3, 8, "SAME")]
    size = n
    n_valid = max((size - 2 + 1) // 2, 0)  # k3-VALID layers until size <= 2
    for i in range(n_valid):
        f = 4 if i == n_valid - 1 else 8  # Table 2: ..., 8, 4, then k2 f1
        plan.append((3, f, "VALID"))
        size -= 2
    # size is now 2 (n even) or 3->... n odd handled: if size==3 a k3 valid
    # layer above would have taken it to 1 already; guard both endings.
    if size == 2:
        plan.append((2, 1, "VALID"))
    else:  # size == 1 after the loop (odd n): make last layer emit 1 filter
        k, _, pad = plan.pop()
        plan.append((k, 1, pad))
    return plan


def _trunk_init(key: jax.Array, cfg: PolicyConfig) -> list[dict]:
    plan = _conv_plan(cfg.n_nodes)
    keys = jax.random.split(key, len(plan))
    params = []
    c_in = cfg.channels
    for k_layer, (ksize, f, _pad) in zip(keys, plan):
        params.append(nn.convnd_init(k_layer, ksize, c_in, f, ndim=cfg.n_dims))
        c_in = f
    return params


def _trunk_apply(params: list[dict], cfg: PolicyConfig, obs: jax.Array) -> jax.Array:
    """obs (..., E, *spatial, C) -> per-element scalar (..., E)."""
    plan = _conv_plan(cfg.n_nodes)
    x = obs
    gains = cfg.active_gains
    if gains is not None:  # declared per-channel input normalization
        x = x * jnp.asarray(gains, x.dtype)
    for i, (p, (_k, _f, pad)) in enumerate(zip(params, plan)):
        x = nn.convnd(p, x, ndim=cfg.n_dims, padding=pad)
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    # spatial reduced to (1,)*n_dims, single filter -> drop those axes
    return x.reshape(x.shape[: -(cfg.n_dims + 1)])


def init(key: jax.Array, cfg: PolicyConfig) -> dict:
    ka, kc = jax.random.split(key)
    return {
        "actor": _trunk_init(ka, cfg),
        "log_std": jnp.full((), cfg.log_std_init, jnp.float32),
        "critic": _trunk_init(kc, cfg),
    }


def actor_mean(params: dict, cfg: PolicyConfig, obs: jax.Array) -> jax.Array:
    """Mean action per element, in [act_low, cs_max]."""
    logits = _trunk_apply(params["actor"], cfg, obs)
    return cfg.act_low + (cfg.cs_max - cfg.act_low) * jax.nn.sigmoid(logits)


def value(params: dict, cfg: PolicyConfig, obs: jax.Array) -> jax.Array:
    """State value: mean of the per-element critic outputs (..., E) -> (...)."""
    return jnp.mean(_trunk_apply(params["critic"], cfg, obs), axis=-1)


def distribution(params: dict, cfg: PolicyConfig, obs: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """(mean, std) of the per-element Gaussian action distribution."""
    mean = actor_mean(params, cfg, obs)
    std = jnp.exp(params["log_std"]).astype(mean.dtype)
    return mean, jnp.broadcast_to(std, mean.shape)


def log_prob(mean: jax.Array, std: jax.Array, action: jax.Array) -> jax.Array:
    """Joint log-density of the element-wise independent Gaussian (sum over E)."""
    z = (action - mean) / std
    per_elem = -0.5 * z * z - jnp.log(std) - 0.5 * math.log(2.0 * math.pi)
    return jnp.sum(per_elem, axis=-1)


def entropy(std: jax.Array) -> jax.Array:
    """Joint entropy (sum over the element axis)."""
    per_elem = 0.5 * math.log(2.0 * math.pi * math.e) + jnp.log(std)
    return jnp.sum(per_elem, axis=-1)


def sample_action(key: jax.Array, params: dict, cfg: PolicyConfig,
                  obs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Draw a ~ N(mean, std); returns (action, log_prob)."""
    mean, std = distribution(params, cfg, obs)
    noise = jax.random.normal(key, mean.shape, mean.dtype)
    action = mean + std * noise
    return action, log_prob(mean, std, action)


def param_count(params: dict) -> int:
    return nn.param_count(params["actor"]) + 1  # actor + log_std (Table 2 scope)


class PolicyFns(NamedTuple):
    """The pure-callable policy interface the training stack consumes.

    `core/rollout.py` and `core/ppo.py` only ever need these four programs;
    bundling them decouples the stack from THIS module's Conv-trunk
    parameterization, so alternative policies (e.g. the multi-scenario
    shared-trunk heads in `fleet/multitask.py`) plug into the unchanged
    rollout scan and PPO update.  Every callable is a pure function of its
    array arguments with the configuration closed over statically.
    """

    sample: Callable[[jax.Array, dict, jax.Array],
                     tuple[jax.Array, jax.Array]]  # (key, params, obs)
    mean: Callable[[dict, jax.Array], jax.Array]                 # (params, obs)
    dist: Callable[[dict, jax.Array], tuple[jax.Array, jax.Array]]
    value: Callable[[dict, jax.Array], jax.Array]


def policy_fns(cfg: PolicyConfig) -> PolicyFns:
    """The default single-scenario policy bound to `cfg` — calling through
    this adapter is call-for-call identical to the direct module functions
    (the pre-adapter graph, pinned by tests/test_fleet.py)."""
    return PolicyFns(
        sample=partial(_sample_cfg, cfg),
        mean=partial(_mean_cfg, cfg),
        dist=partial(_dist_cfg, cfg),
        value=partial(_value_cfg, cfg),
    )


# Module-level partials (not lambdas) keep PolicyFns values comparable and
# picklable; each simply re-orders (cfg, ...) into the public signatures.
def _sample_cfg(cfg, key, params, obs):
    return sample_action(key, params, cfg, obs)


def _mean_cfg(cfg, params, obs):
    return actor_mean(params, cfg, obs)


def _dist_cfg(cfg, params, obs):
    return distribution(params, cfg, obs)


def _value_cfg(cfg, params, obs):
    return value(params, cfg, obs)

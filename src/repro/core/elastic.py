"""Elastic restart: resume a checkpoint on a *different* mesh / fleet size.

The paper's framework is tied to its batch allocation (N nodes reserved for
the whole training run).  On cloud TPU pods, slices get preempted and
re-materialize at different sizes — so checkpoint restore must tolerate a
mesh-shape change.  Two layers:

  * `reshard`      : host-roundtrip-free re-placement of a pytree onto a new
                     mesh given PartitionSpecs (falls back to host transfer
                     when source and target topologies are incompatible).
  * `elastic_fleet`: adjust the environment-fleet size between iterations.
    PPO is on-policy — experience never outlives an iteration — so fleet
    size is a *free* elastic knob: shrinking/growing n_envs only changes the
    gradient-estimator variance (paper Sec. 6.2), never correctness.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def reshard(tree: Any, mesh: Mesh, specs: Any) -> Any:
    """Place `tree` on `mesh` with `specs` (a pytree of PartitionSpec or a
    single spec broadcast to all leaves)."""
    if isinstance(specs, PartitionSpec):
        specs = jax.tree.map(lambda _: specs, tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )


def validate_divisibility(shape: tuple[int, ...], spec: PartitionSpec,
                          mesh: Mesh) -> bool:
    """True iff every sharded dim of `shape` divides its mesh-axis product."""
    for dim, axes in zip(shape, spec):
        if axes is None:
            continue
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % total:
            return False
    return True


def elastic_fleet(n_envs_ckpt: int, mesh: Mesh | None,
                  env_axes: tuple[str, ...] = ("data",)) -> int:
    """Fleet size to run on the *current* mesh, given the checkpointed one.

    Keeps the per-shard env count of the checkpointed run when possible,
    otherwise rounds the fleet to a multiple of the env-shard count.  Returns
    the adjusted n_envs (== n_envs_ckpt when the mesh still divides it).
    """
    if mesh is None:
        return n_envs_ckpt
    shards = int(np.prod([mesh.shape[a] for a in env_axes]))
    if n_envs_ckpt % shards == 0:
        return n_envs_ckpt
    return max(1, round(n_envs_ckpt / shards)) * shards

"""Fault-tolerant RL training runner (paper Algorithm 1, production-hardened).

Determinism contract
--------------------
Iteration k is a pure function of (seed, k, params_k, opt_k): the rollout key
is `fold_in(seed_key, k)` and initial states are drawn from the device bank.
Consequences for a 1000-node fleet:

  * node failure      -> resume from the newest complete checkpoint and
                         re-execute iterations deterministically (no
                         divergence between the original and replayed run);
  * straggler shards  -> the fleet program is bulk-synchronous SPMD; there is
                         no per-environment scheduling to go astray.  Slow
                         *hosts* (data feeding, checkpoint writes) are taken
                         off the critical path: checkpoints are written by a
                         background thread from host copies;
  * elastic restart   -> `Runner.restore` re-places the state on the current
                         mesh (core/elastic.py) and adjusts the fleet size.

A `failure_injector` hook (tests) raises mid-iteration to exercise the
recovery path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .. import optim
from ..envs.base import Env
from . import checkpoints, policy as policy_lib, ppo as ppo_lib
from .orchestrator import FleetConfig, Orchestrator


@dataclasses.dataclass(frozen=True)
class RunnerConfig:
    n_iterations: int = 100
    eval_every: int = 10          # paper: test state evaluated every 10 iters
    checkpoint_every: int = 25
    checkpoint_dir: str = "checkpoints/relexi"
    metrics_path: str | None = None  # jsonl; default <ckpt_dir>/metrics.jsonl
    keep_checkpoints: int = 3
    seed: int = 0
    async_checkpoint: bool = True


class RunnerBase:
    """Checkpoint + metrics plumbing shared by training loops.

    The single-scenario `Runner` below and the multi-scenario fleet runner
    (`fleet/pipeline.py`) carry different state trees (one policy vs. the
    multitask tree + broker rings) but share the same durability contract:
    atomic versioned checkpoints written off the critical path by a
    background thread, template-based restore, and a jsonl metrics stream.
    Subclasses define `_state_tree` / `_load_state` / `_checkpoint_meta`.
    """

    run_cfg: RunnerConfig

    def __init__(self, run_cfg: RunnerConfig | None):
        self.run_cfg = run_cfg or RunnerConfig()
        self.iteration = 0
        self._ckpt_thread: threading.Thread | None = None
        self.metrics_path = self.run_cfg.metrics_path or os.path.join(
            self.run_cfg.checkpoint_dir, "metrics.jsonl")

    # --- subclass hooks -------------------------------------------------------
    def _state_tree(self) -> dict:
        """The checkpointed device state (template for restore)."""
        raise NotImplementedError

    def _load_state(self, tree: dict, manifest: dict) -> None:
        """Install a restored state tree + manifest onto self."""
        raise NotImplementedError

    def _checkpoint_meta(self) -> dict:
        return {"iteration": self.iteration, "seed": self.run_cfg.seed}

    # --- checkpoint plumbing --------------------------------------------------
    def save_checkpoint(self, block: bool = False) -> None:
        tree = jax.device_get(self._state_tree())  # host copy off critical path
        meta = self._checkpoint_meta()
        step = self.iteration

        def write():
            checkpoints.save(self.run_cfg.checkpoint_dir, step, tree,
                             meta=meta, keep=self.run_cfg.keep_checkpoints)

        self.join_pending_checkpoint()  # never two concurrent writers
        if self.run_cfg.async_checkpoint and not block:
            self._ckpt_thread = threading.Thread(target=write, daemon=True)
            self._ckpt_thread.start()
        else:
            write()

    def join_pending_checkpoint(self) -> None:
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None

    def restore(self) -> bool:
        """Resume from the newest complete checkpoint; returns True if found."""
        step = checkpoints.latest_step(self.run_cfg.checkpoint_dir)
        if step is None:
            return False
        tree, manifest = checkpoints.restore(
            self.run_cfg.checkpoint_dir, step, self._state_tree())
        self._load_state(tree, manifest)
        return True

    # --- metrics ---------------------------------------------------------------
    def _log(self, record: dict) -> None:
        os.makedirs(os.path.dirname(self.metrics_path) or ".", exist_ok=True)
        with open(self.metrics_path, "a") as f:
            f.write(json.dumps(record) + "\n")


class Runner(RunnerBase):
    def __init__(
        self,
        env: Env,
        fleet: FleetConfig,
        ppo_cfg: ppo_lib.PPOConfig | None = None,
        run_cfg: RunnerConfig | None = None,
        *,
        mesh=None,
        failure_injector: Callable[[int], None] | None = None,
    ):
        super().__init__(run_cfg)
        self.ppo_cfg = ppo_cfg or ppo_lib.PPOConfig()
        self.orch = Orchestrator(env, fleet, mesh=mesh, seed=self.run_cfg.seed)
        self.failure_injector = failure_injector

        key = jax.random.PRNGKey(self.run_cfg.seed)
        self.seed_key, init_key = jax.random.split(key)
        self.params = policy_lib.init(init_key, self.orch.pcfg)
        self.opt_state = optim.adam_init(self.params)

        self._update = jax.jit(
            lambda p, o, t: ppo_lib.update(p, o, self.ppo_cfg, self.orch.pcfg, t)
        )

    # --- checkpoint hooks -----------------------------------------------------
    def _state_tree(self) -> dict:
        return {"params": self.params, "opt": self.opt_state}

    def _load_state(self, tree: dict, manifest: dict) -> None:
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.iteration = int(manifest["meta"]["iteration"])

    def _checkpoint_meta(self) -> dict:
        return {**super()._checkpoint_meta(), "n_envs": self.orch.fleet.n_envs}

    # --- training ---------------------------------------------------------------
    def run_iteration(self, k: int) -> dict:
        """One synchronous PPO iteration (sample fleet -> n_epochs updates)."""
        key = jax.random.fold_in(self.seed_key, k)
        t0 = time.perf_counter()
        traj = self.orch.sample_fleet(self.params, key)
        traj = jax.block_until_ready(traj)
        t_sample = time.perf_counter() - t0
        if self.failure_injector is not None:
            self.failure_injector(k)  # may raise — exercised by tests
        t0 = time.perf_counter()
        new_params, new_opt, stats = self._update(
            self.params, self.opt_state, traj)
        stats = jax.device_get(stats)
        # never let a non-finite update poison the params / checkpoints:
        # keep the previous state and record the skip (env-level blow-up
        # guards make this a last line of defense, not the common path)
        if not all(jnp.isfinite(v).all() for v in stats.values()):
            self._log({"iteration": k, "skipped_nonfinite_update": True})
        else:
            self.params, self.opt_state = new_params, new_opt
        t_update = time.perf_counter() - t0
        record = {
            "iteration": k,
            "t_sample_s": t_sample,
            "t_update_s": t_update,
            # episode length read off the trajectory, not the env config —
            # envs with different horizons keep the metric correct
            "return_norm": float(stats["mean_return"]) / traj.rewards.shape[0],
            **{f"ppo/{n}": float(v) for n, v in stats.items()},
        }
        return record

    def train(self, n_iterations: int | None = None, *, resume: bool = True,
              max_retries: int = 2) -> list[dict]:
        """The full loop with crash recovery.  Returns per-iteration records."""
        total = n_iterations or self.run_cfg.n_iterations
        if resume:
            self.restore()
        history: list[dict] = []
        while self.iteration < total:
            k = self.iteration
            for attempt in range(max_retries + 1):
                try:
                    record = self.run_iteration(k)
                    break
                except RuntimeError as e:  # injected / transient failure
                    if attempt == max_retries:
                        raise
                    # deterministic replay: restore the consistent state and retry
                    if not self.restore():
                        pass  # no checkpoint yet: params/opt unchanged pre-update
                    record = {"iteration": k, "retry": attempt + 1, "error": str(e)}
                    self._log(record)
            if (k + 1) % self.run_cfg.eval_every == 0:
                record["eval_return_norm"] = float(self.orch.evaluate(self.params))
            self._log(record)
            history.append(record)
            self.iteration = k + 1
            if (k + 1) % self.run_cfg.checkpoint_every == 0:
                self.save_checkpoint()
        self.save_checkpoint(block=True)
        self.join_pending_checkpoint()
        return history

"""The paper's primary contribution: scalable synchronous RL-CFD coupling.

  policy        Table-2 Conv3D Gaussian policy (+ critic)
  ppo           clip-PPO with GAE (paper hyperparameters)
  rollout       sharded synchronous fleet rollout (SmartSim-loop analog)
  orchestrator  env-fleet placement, state bank, jitted fleet programs
  runner        fault-tolerant training loop (checkpoint/restart/replay)
  checkpoints   atomic versioned integrity-checked checkpoints
  compression   compressed / chunked cross-pod gradient reduction
  elastic       mesh-shape-changing restarts, elastic fleet sizing
"""
from . import checkpoints, compression, elastic, orchestrator, policy, ppo, rollout, runner

__all__ = [
    "checkpoints",
    "compression",
    "elastic",
    "orchestrator",
    "policy",
    "ppo",
    "rollout",
    "runner",
]

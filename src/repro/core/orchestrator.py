"""Environment orchestrator — the Relexi/SmartSim-IL analog.

The paper's orchestrator (i) launches N FLEXI instances per iteration,
(ii) stages restart files on RAM disks, and (iii) brokers state/action
traffic through a KeyDB in-memory store.  On a TPU mesh all three collapse
into array placement:

  (i)   the environment fleet is one batched array sharded over the
        (pod, data) mesh axes; "launching" is `device_put` once,
  (ii)  the initial-state bank is device-resident (generated once by the
        env's `initial_state_bank` hook, indexed per episode — the RAM-disk
        trick taken to its endpoint),
  (iii) state/action exchange is a mesh-local einsum inside one jitted
        program; there is no database round-trip to optimize.

The orchestrator is generic over the Env protocol (envs/base.py): it owns
ONLY fleet layout/sharding and the state bank; physics, specs, and rewards
live in the env, and the policy heads are built from the env's specs.

The fleet bookkeeping that matters for fault tolerance is unchanged:
environments are *recomputable by construction* — episode i of iteration k
is fully determined by (seed, k, bank index), so replacing a failed shard
means re-running a slice of the same pure function rather than
re-scheduling an MPI job (see core/runner.py for the restart path).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..envs.base import Env, as_env
from . import policy as policy_lib
from . import ppo as ppo_lib
from . import rollout as rollout_lib


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_envs: int = 16          # parallel environments (paper: 16/32/64...1024)
    bank_size: int = 17       # initial states; last one is the held-out test
    env_axes: tuple[str, ...] = ("data",)   # mesh axes the env batch shards over
    elem_axis: str | None = None  # optional 'model' axis for element space


class Orchestrator:
    """Owns the env fleet layout, the state bank, and jitted rollout/update."""

    def __init__(
        self,
        env: Env,
        fleet: FleetConfig,
        *,
        mesh: Mesh | None = None,
        seed: int = 0,
        policy: policy_lib.PolicyFns | None = None,
        pcfg: policy_lib.PolicyConfig | None = None,
    ):
        self.env = as_env(env)  # legacy HITConfig call sites coerce here
        self.fleet = fleet
        self.mesh = mesh
        # `policy` plugs an external policy bundle into the jitted fleet
        # programs (the fleet subsystem's per-scenario multitask heads);
        # left None, the heads are built from the env's specs exactly as
        # before.  `pcfg` may override the spec-derived config (it is unused
        # when `policy` is given).
        self.policy = policy
        self.pcfg = pcfg if pcfg is not None else (
            None if policy is not None else
            policy_lib.PolicyConfig.from_specs(
                self.env.obs_spec, self.env.action_spec
            ))
        key = jax.random.PRNGKey(seed)
        self.bank_key, self.run_key = jax.random.split(key)
        # Device-resident initial-state bank; index -1 is the unseen test state.
        bank = self.env.initial_state_bank(self.bank_key, fleet.bank_size)
        if mesh is not None:
            # Bank is replicated over env shards (every shard may draw any
            # initial state); the env's leading element axis optionally
            # shards over `model`.  Specs are built from the bank's rank so
            # any state layout (3-D HIT, 1-D Burgers, ...) places correctly.
            espec = fleet.elem_axis if fleet.elem_axis else None
            rest = (None,) * (bank.ndim - 2)
            bank = jax.device_put(bank, NamedSharding(mesh, P(None, espec, *rest)))
            self.env_spec = P(fleet.env_axes, espec, *rest)
        else:
            self.env_spec = None
        self.bank = bank

    @property
    def env_cfg(self):
        """The env's static config (back-compat accessor)."""
        return self.env.cfg

    # --- episode setup ------------------------------------------------------
    def draw_initial_states(self, key: jax.Array, n_envs: int | None = None
                            ) -> jax.Array:
        """Random bank rows (excluding the held-out test state), (B, ...).

        `n_envs=None` means the configured fleet size; an explicit count
        must be positive (`n_envs=0` used to fall through a truthiness
        check and silently sample the FULL fleet).
        """
        if n_envs is not None and n_envs <= 0:
            raise ValueError(
                f"n_envs must be a positive environment count, got {n_envs} "
                "(pass None for the configured fleet size)")
        n = self.fleet.n_envs if n_envs is None else n_envs
        idx = jax.random.randint(key, (n,), 0, self.fleet.bank_size - 1)
        u0 = jnp.take(self.bank, idx, axis=0)
        if self.mesh is not None:
            u0 = jax.lax.with_sharding_constraint(
                u0, NamedSharding(self.mesh, self.env_spec))
        return u0

    def test_state(self) -> jax.Array:
        """The single held-out initial state, batched to (1, ...)."""
        return self.bank[-1][None]

    # --- jitted fleet programs ----------------------------------------------
    @partial(jax.jit, static_argnums=(0,))
    def sample_fleet(self, params: dict, key: jax.Array) -> ppo_lib.Trajectory:
        """One synchronous sampling pass over the whole fleet (paper Alg. 1
        lines 4-13, all environments at once)."""
        k_init, k_roll = jax.random.split(key)
        u0 = self.draw_initial_states(k_init)
        return rollout_lib.rollout(params, self.pcfg, self.env, u0, k_roll,
                                   policy=self.policy)

    @partial(jax.jit, static_argnums=(0,))
    def evaluate(self, params: dict) -> jax.Array:
        """Deterministic (mean-action) episode on the held-out state ->
        normalized return, as the paper's test-state curve in Fig. 5."""
        traj = rollout_lib.rollout(
            params, self.pcfg, self.env, self.test_state(),
            jax.random.PRNGKey(0), deterministic=True, policy=self.policy,
        )
        return rollout_lib.normalized_return(traj)[0]

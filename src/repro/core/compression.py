"""Gradient compression for the cross-pod (DCN) all-reduce.

Within a pod, gradient reduction rides the ICI fabric and is cheap relative
to compute; *between* pods it crosses the data-center network, which is an
order of magnitude slower.  The classic mitigation is to compress only the
slow-axis reduction:

    grads --psum(ici axes)--> pod-local sum --compress--> psum(pod axis)
          --decompress--> update

Two codecs are provided:
  * bf16    : 2x volume, unbiased-ish truncation (round-to-nearest-even)
  * int8    : 4x volume, per-leaf absmax scaling + ERROR FEEDBACK — the
              quantization residual is carried to the next iteration, which
              keeps SGD/Adam convergence intact (Seide et al. 2014; Karimireddy
              et al. 2019).

All functions are shard_map-friendly: `compressed_psum` must be called inside
a shard_map (or pmapped) context where `axis_name` is bound.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    tree: Any,
    axis_name: str,
    *,
    method: str = "bf16",
    error_state: Any = None,
) -> tuple[Any, Any]:
    """All-reduce `tree` over `axis_name` with on-the-wire compression.

    Returns (reduced_tree_f32, new_error_state).  `error_state` (same
    structure, f32) carries the int8 quantization residuals between calls;
    pass None to start from zero (also valid for bf16/none, where it stays
    None).
    """
    if method == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), tree), None

    if method == "bf16":
        def red(g):
            g16 = g.astype(jnp.bfloat16)
            return jax.lax.psum(g16, axis_name).astype(jnp.float32)

        return jax.tree.map(red, tree), None

    if method == "int8":
        if error_state is None:
            error_state = jax.tree.map(
                lambda g: jnp.zeros_like(g, dtype=jnp.float32), tree)

        def red(g, err):
            g = g.astype(jnp.float32) + err
            q, scale = _quantize_int8(g)
            residual = g - _dequantize_int8(q, scale)
            # int8 sums overflow; widen to int32 on the wire-equivalent psum.
            # (XLA transfers the widened type; the 4x volume claim holds for a
            # real wire codec — we model the *numerics* here and account the
            # traffic analytically in benchmarks/roofline.py.)
            total = jax.lax.psum(q.astype(jnp.int32), axis_name)
            scale_sum = jax.lax.pmax(scale, axis_name)  # shared conservative scale
            return total.astype(jnp.float32) * scale_sum, residual

        flat, tdef = jax.tree.flatten(tree)
        errs = jax.tree.leaves(error_state)
        outs = [red(g, e) for g, e in zip(flat, errs)]
        reduced = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_err = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return reduced, new_err

    raise ValueError(f"unknown compression method: {method}")


def chunked_psum(tree: Any, axis_name: str, *, n_chunks: int = 4) -> Any:
    """Split each leaf into chunks and psum them independently.

    XLA schedules independent collectives concurrently with surrounding
    compute — chunking exposes the overlap window (the 'interleaved gradient
    reduction' trick; see EXPERIMENTS.md §Perf).
    """
    def red(g):
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % n_chunks
        flat = jnp.pad(flat, (0, pad))
        chunks = jnp.split(flat, n_chunks)
        out = jnp.concatenate([jax.lax.psum(c, axis_name) for c in chunks])
        return out[: g.size].reshape(g.shape)

    return jax.tree.map(red, tree)

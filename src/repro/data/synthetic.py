"""Deterministic synthetic data pipeline for the LM cells.

A production run would stream tokenized shards; offline we generate a
reproducible Zipf-ish token stream whose cursor is part of the checkpoint
(fault-tolerant resume replays the exact same batches).  Modality frontends
are stubs per the brief: `make_batch_for` attaches precomputed patch/frame
embeddings for the vlm/audio architectures.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig


def _zipf_tokens(rng: np.random.Generator, shape: tuple[int, ...], vocab: int
                 ) -> np.ndarray:
    """Zipf(1.2)-distributed token ids in [0, vocab) — a crude natural-text
    frequency profile so losses have realistic magnitude/structure."""
    z = rng.zipf(1.2, size=shape).astype(np.int64)
    return (z % vocab).astype(np.int32)


def lm_batch(seed: int, batch: int, seq: int, vocab: int) -> dict:
    """One (tokens, labels) next-token batch."""
    rng = np.random.default_rng(seed)
    stream = _zipf_tokens(rng, (batch, seq + 1), vocab)
    return {
        "tokens": jnp.asarray(stream[:, :-1]),
        "labels": jnp.asarray(stream[:, 1:]),
    }


def make_batch_for(cfg: ArchConfig, seed: int, batch: int, seq: int) -> dict:
    """Cell-shaped batch for `cfg` including stub modality inputs."""
    if cfg.is_encdec:  # whisper: frames are the stub conv-frontend output
        rng = np.random.default_rng(seed)
        out = lm_batch(seed, batch, seq, cfg.vocab)
        out["frames"] = jnp.asarray(
            rng.standard_normal(
                (batch, cfg.max_source_positions, cfg.d_model),
                dtype=np.float32))
        return out
    if cfg.vision_dim:  # llava: anyres patch embeddings, text fills the rest
        text = max(seq - cfg.vision_tokens, 8)
        rng = np.random.default_rng(seed)
        out = lm_batch(seed, batch, text, cfg.vocab)
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.vision_tokens, cfg.vision_dim),
                                dtype=np.float32))
        return out
    return lm_batch(seed, batch, seq, cfg.vocab)


@dataclasses.dataclass
class TokenStream:
    """Checkpointable deterministic batch iterator."""

    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    cursor: int = 0

    def next(self) -> dict:
        b = make_batch_for(self.cfg, self.seed + self.cursor, self.batch,
                           self.seq)
        self.cursor += 1
        return b

    def state_dict(self) -> dict:
        return {"seed": self.seed, "cursor": self.cursor}

    def load_state_dict(self, s: dict) -> None:
        self.seed, self.cursor = int(s["seed"]), int(s["cursor"])

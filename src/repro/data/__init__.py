"""Data pipeline: deterministic synthetic streams for the LM cells and
episode initial states for the RL-CFD cells (see cfd/initial.py)."""
from .synthetic import TokenStream, lm_batch, make_batch_for

__all__ = ["TokenStream", "lm_batch", "make_batch_for"]

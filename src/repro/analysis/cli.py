"""`repro-lint`: run the static-analysis gate and emit the report.

    repro-lint                            # AST + jaxpr + kernel layers
    repro-lint --layers all               # + the trace certification run
    repro-lint --layers ast               # source lint only (fast)
    repro-lint --report analysis_report.json

Exit status is 0 iff there are zero unsuppressed findings — the CI
`static-analysis` job gates on exactly this.  The JSON report is written
either way so a red run still uploads its artifact.
"""
from __future__ import annotations

import argparse
import sys

from .report import Report

LAYERS = ("ast", "jaxpr", "kernel", "trace")
DEFAULT_LAYERS = ("ast", "jaxpr", "kernel")


def run_layers(layers: tuple[str, ...], root: str = ".") -> Report:
    report = Report()
    if "ast" in layers:
        from . import ast_rules
        ast_rules.run(report, root=root)
    if "jaxpr" in layers:
        from . import jaxpr_audit
        jaxpr_audit.run(report)
    if "kernel" in layers:
        from . import kernel_audit
        kernel_audit.run(report)
    if "trace" in layers:
        from . import trace_audit
        trace_audit.run(report)
    report.meta["layers"] = list(layers)
    return report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--layers", default=",".join(DEFAULT_LAYERS),
                    help="comma-separated subset of "
                         f"{','.join(LAYERS)}, or 'all' "
                         f"(default: {','.join(DEFAULT_LAYERS)}; 'trace' "
                         "runs a real reduced training run)")
    ap.add_argument("--report", default="analysis_report.json",
                    help="path for the machine-readable report "
                         "(default: %(default)s)")
    ap.add_argument("--root", default=".",
                    help="repo root to lint (default: cwd)")
    args = ap.parse_args(argv)

    layers = (LAYERS if args.layers == "all"
              else tuple(l.strip() for l in args.layers.split(",") if l.strip()))
    unknown = set(layers) - set(LAYERS)
    if unknown:
        ap.error(f"unknown layer(s) {sorted(unknown)}; choose from {LAYERS}")

    report = run_layers(layers, root=args.root)
    report.save(args.report)
    print(report.summary())
    print(f"report: {args.report}")
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())

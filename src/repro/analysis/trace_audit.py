"""Layer-1 trace auditor: pin compile counts, flag silent retraces.

A jitted entry point that retraces per call (a Python-object static arg
rebuilt each iteration, a weak-typed scalar flipping dtype, a shape that
drifts) silently turns a compiled training loop into a compile loop — the
steady-state invariant of this codebase is **one trace per distinct
shape**.  XLA never errors on this; it just gets slow.  This auditor makes
it a gated finding (TRACE001):

* `watch({...})` is the generic primitive — snapshot `_cache_size()` of a
  set of jitted callables, run a body, report the growth.  The benchmark
  harnesses wrap their timed sections in it so published perf JSONs carry
  certified compile counts.
* `run()` drives a short reduced-HIT training run (rollout -> PPO update
  -> held-out eval, real `Runner.train`) and pins the exact expected
  counts for every hot program it exercises.

Compile-count bookkeeping uses jit's `_cache_size()`; counts are measured
as *growth* between snapshots so a polluted cache (pytest reordering,
prior cells) cannot fake a pass or a failure.
"""
from __future__ import annotations

import tempfile
from typing import Any, Callable, Mapping

from .report import Finding, Report

# The pinned contract for one reduced-HIT training run (run() below):
# exactly one trace per distinct program x batch shape.  `sample_fleet`
# and `evaluate` are class-level jits on Orchestrator (fleet-batch and
# batch-1 shapes respectively — one trace each); the env's
# `advance_rl_interval` is pinned at ZERO standalone compiles: it only
# ever runs inlined inside those outer programs (nested jits trace under
# the parent's cache), so any growth here means a host loop is calling
# the solver eagerly per iteration — the exact dispatch-overhead failure
# mode the paper's single-program design exists to avoid.
EXPECTED_REDUCED_HIT: dict[str, int] = {
    "sample_fleet": 1,
    "evaluate": 1,
    "ppo_update": 1,
    "hit_advance_rl_interval": 0,
}


class TraceWatch:
    """Context manager: cache-size growth of jitted fns across a body."""

    def __init__(self, fns: Mapping[str, Any]):
        for name, fn in fns.items():
            if not hasattr(fn, "_cache_size"):
                raise TypeError(
                    f"{name!r} is not a jitted callable (no _cache_size); "
                    "pass the jax.jit wrapper itself, not the python fn")
        self.fns = dict(fns)
        self.growth: dict[str, int] = {}
        self._before: dict[str, int] = {}

    def __enter__(self) -> "TraceWatch":
        self._before = {n: f._cache_size() for n, f in self.fns.items()}
        return self

    def __exit__(self, *exc) -> None:
        self.growth = {n: f._cache_size() - self._before[n]
                       for n, f in self.fns.items()}

    def check(self, expected: Mapping[str, int],
              entrypoint: str = "") -> list[Finding]:
        """TRACE001 findings for every fn whose growth != its pin."""
        findings = []
        for name, want in expected.items():
            got = self.growth.get(name)
            if got != want:
                findings.append(Finding(
                    rule="TRACE001",
                    message=(f"`{name}` compiled {got} time(s), pinned "
                             f"{want} — "
                             + ("silent retrace" if (got or 0) > want
                                else "stale pin / dead program")),
                    entrypoint=entrypoint or name))
        return findings


def watch(fns: Mapping[str, Any]) -> TraceWatch:
    return TraceWatch(fns)


def certify(fns: Mapping[str, Any], expected: Mapping[str, int],
            body: Callable[[], Any]) -> tuple[Any, dict[str, int]]:
    """Benchmark-harness helper: run `body`, assert the pinned compile
    counts, return (body result, certified counts) — the counts go into
    the perf JSON artifact.  Raises RuntimeError on any mismatch: perf
    numbers from a retracing program must not be published."""
    with watch(fns) as w:
        result = body()
    bad = w.check(expected, entrypoint="benchmark")
    if bad:
        raise RuntimeError(
            "trace certification failed:\n  "
            + "\n  ".join(f.message for f in bad))
    return result, dict(w.growth)


def run(report: Report | None = None, n_iterations: int = 3) -> Report:
    """The reduced-HIT certification: a real 3-iteration training run with
    one held-out eval, against `EXPECTED_REDUCED_HIT`."""
    import jax

    from .. import envs
    from ..cfd import solver
    from ..core.orchestrator import FleetConfig, Orchestrator
    from ..core.runner import Runner, RunnerConfig

    report = report or Report()
    # distinctive physics override -> a config no other test has traced, so
    # every count below starts from a guaranteed-fresh cache entry
    env = envs.make("hit_les_reduced", t_end=0.41)
    runner = Runner(
        env, FleetConfig(n_envs=2, bank_size=5),
        run_cfg=RunnerConfig(
            n_iterations=n_iterations, eval_every=2,
            checkpoint_every=10 * n_iterations, async_checkpoint=False,
            checkpoint_dir=tempfile.mkdtemp(prefix="repro_trace_audit_")))

    tracked = {
        "sample_fleet": Orchestrator.sample_fleet,
        "evaluate": Orchestrator.evaluate,
        "ppo_update": runner._update,
        "hit_advance_rl_interval": solver.advance_rl_interval,
    }
    with watch(tracked) as w:
        runner.train(n_iterations, resume=False)
    report.extend(w.check(EXPECTED_REDUCED_HIT, entrypoint="reduced_hit_run"))
    report.meta.setdefault("trace_audit", {})["reduced_hit_compile_counts"] = (
        dict(w.growth))
    return report

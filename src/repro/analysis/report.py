"""Findings, rules, suppressions and the `analysis_report.json` schema.

Every auditor layer (AST lint, jaxpr/HLO program audits, trace audit,
kernel audit) reduces to the same currency: a `Finding` — one rule
violation pinned to a location — collected into a `Report`.  The report
serializes to `analysis_report.json` (the CI artifact uploaded next to the
perf JSONs) and renders a human summary; the exit code of `repro-lint` is
derived from `Report.unsuppressed()`.

Suppressions
------------
AST-layer findings can be suppressed inline at the offending line:

    x = np.tanh(y)  # repro-lint: disable=AST001 -- trace-time table build

The reason string after ` -- ` is MANDATORY: a suppression without a
reason is itself a finding (AST007).  Program-layer findings (JAX*/TRACE*/
KERN* rules) are suppressed in the entry-point registry instead
(`entrypoints.EntryPoint.suppress`), again with a required reason — the
registry is reviewed code, so every waiver is diffable.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable

SCHEMA_VERSION = 1

# The rule catalog: id -> (severity, one-line description).  docs/
# static_analysis.md carries the long-form catalog; tests/test_analysis.py
# red-teams every id with a deliberately violating fixture.
RULES: dict[str, tuple[str, str]] = {
    # --- layer 2: source lint (ast_rules.py) --------------------------------
    "AST001": ("error", "numpy op inside a function body of a jit-reachable "
                        "module (host math silently breaks tracing/vmap)"),
    "AST002": ("error", "Python `random` in a jit-reachable module (untraced "
                        "RNG breaks replay determinism)"),
    "AST003": ("error", "bare RK-style numpy scalar constant in arithmetic "
                        "without float() wrap (f64 weak scalar re-promotes "
                        "the bf16/f32 carry)"),
    "AST004": ("error", "jnp.float64 literal (x64 is never enabled in "
                        "production; f64 doubles HBM traffic)"),
    "AST005": ("error", "Pallas kernel signature defaults `interpret` to a "
                        "concrete bool instead of None (backend policy "
                        "bypass)"),
    "AST006": ("error", "envs.make() called with a name missing from the "
                        "registry (example/benchmark rot)"),
    "AST007": ("error", "repro-lint suppression without a ` -- reason` "
                        "string"),
    # --- layer 1: program auditors ------------------------------------------
    "JAX001": ("error", "float64 value inside a hot jitted program"),
    "JAX002": ("error", "state-sized f32 round-trip inside the declared bf16 "
                        "interval (dtype churn)"),
    "JAX003": ("error", "host callback (pure_callback/debug_callback/"
                        "io_callback) inside a hot jitted program"),
    "JAX004": ("error", "declared donated buffer is not aliased in the "
                        "lowered program (donation silently dropped)"),
    "JAX005": ("warning", "large output buffer with no donated aliasing on "
                          "an entry point declared as donating"),
    "TRACE001": ("error", "entry point retraced beyond its pinned compile "
                          "count (silent retrace)"),
    "KERN001": ("error", "Pallas kernel closes over an array constant "
                         "(fails TPU lowering; pass it as an input)"),
    "KERN002": ("error", "Pallas block shape does not divide the padded "
                         "array dim (partial blocks corrupt/wast VMEM)"),
    "KERN003": ("warning", "estimated kernel VMEM footprint exceeds the "
                           "budget"),
}


@dataclasses.dataclass
class Finding:
    """One rule violation pinned to a location."""

    rule: str
    message: str
    file: str = ""
    line: int = 0
    entrypoint: str = ""     # program-layer findings: the registry entry
    suppressed: bool = False
    suppress_reason: str = ""

    @property
    def severity(self) -> str:
        return RULES.get(self.rule, ("error", ""))[0]

    @property
    def location(self) -> str:
        if self.file:
            return f"{self.file}:{self.line}" if self.line else self.file
        return self.entrypoint or "<program>"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "file": self.file,
            "line": self.line,
            "entrypoint": self.entrypoint,
            "message": self.message,
            "suppressed": self.suppressed,
            "suppress_reason": self.suppress_reason,
        }


@dataclasses.dataclass
class Report:
    """All findings from one analysis run + layer metadata (compile counts,
    kernel VMEM estimates, ...) that the JSON artifact carries for CI
    trend-tracking even when everything is clean."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def unsuppressed(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def clean(self) -> bool:
        return not self.unsuppressed()

    def to_dict(self) -> dict:
        by_rule: dict[str, int] = {}
        for f in self.unsuppressed():
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        return {
            "schema_version": SCHEMA_VERSION,
            "clean": self.clean,
            "n_findings": len(self.unsuppressed()),
            "n_suppressed": sum(f.suppressed for f in self.findings),
            "findings_by_rule": by_rule,
            "findings": [f.to_dict() for f in self.findings],
            "meta": self.meta,
        }

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    def summary(self) -> str:
        """Human-readable digest — what `repro-lint` prints."""
        lines = []
        live = self.unsuppressed()
        for f in sorted(live, key=lambda f: (f.rule, f.location)):
            lines.append(f"{f.severity.upper():7s} {f.rule} {f.location}: "
                         f"{f.message}")
        n_sup = sum(f.suppressed for f in self.findings)
        for f in (f for f in self.findings if f.suppressed):
            lines.append(f"supp.   {f.rule} {f.location}: "
                         f"{f.suppress_reason}")
        verdict = ("clean" if not live
                   else f"{len(live)} unsuppressed finding(s)")
        lines.append(f"repro-lint: {verdict}"
                     + (f" ({n_sup} suppressed)" if n_sup else ""))
        return "\n".join(lines)

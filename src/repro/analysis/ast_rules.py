"""Layer-2 source lint: AST rules enforcing the repo's jit idioms.

These are the conventions PR reviews kept re-litigating, promoted to
machine checks (ids in `report.RULES`):

AST001  `np.<fn>(...)` inside a *traced function* of a jit-reachable
        module.  numpy silently concretizes tracers (or runs per-call on
        the host).  "Traced" is the repo's signature convention: any
        function with a `jax.Array`-annotated parameter.  Host-side table
        builders (annotated `np.ndarray`/config-only params), module-level
        operator tables, and `@property` config math are exempt — those
        run at trace/config time by design.
AST002  Python `random` in a jit-reachable module: untraced RNG breaks
        the bit-replayable checkpoint contract.
AST003  subscripting a module-level numpy array constant directly in
        arithmetic (`_RK_A[stage] * du`).  The element is a numpy f64
        scalar — it re-promotes a bf16/f32 carry; the convention is
        `float(_RK_A[stage])` (a weak Python float cannot promote).
AST004  `jnp.float64` literal anywhere.
AST005  a kernel-module function signature defaulting `interpret` to a
        concrete bool — kernels must default `interpret=None` so
        `policy.resolve_interpret` keeps backend selection centralized.
AST006  `envs.make("<name>")` with a literal name missing from the
        registry (examples/benchmarks rot when scenarios are renamed).
AST007  a `# repro-lint: disable=...` comment without a ` -- reason`.

Suppression: append `# repro-lint: disable=AST001 -- <reason>` to the
offending line.  Multiple ids comma-separate; the reason is mandatory.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable

from .report import Finding, Report

# jit-reachable module set for AST001/AST002/AST003: everything that can
# end up inside a traced program.  Paths relative to the repo root.
HOT_PREFIXES = (
    "src/repro/envs/",
    "src/repro/cfd/",
    "src/repro/kernels/",
    "src/repro/fleet/",
    "src/repro/optim/",
    "src/repro/core/",
    "src/repro/serve/",
)
# host-side orchestration inside those packages (never traced)
HOT_EXCLUDES = (
    "src/repro/core/runner.py",      # checkpoint/metrics host loop
    "src/repro/core/elastic.py",     # host-side pool management
    "src/repro/fleet/pipeline.py",   # host loop around the jitted programs
    "src/repro/fleet/scheduler.py",  # schedule built once on the host
    "src/repro/kernels/policy.py",   # env-var policy, host only
    "src/repro/serve/batcher.py",    # host-side request queues / padding
    "src/repro/serve/loader.py",     # checkpoint restore on the host
)

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Z0-9,\s]+?)(?:\s*--\s*(.*\S))?\s*$")


def _suppressions(src: str) -> tuple[dict[int, tuple[set, str]], list]:
    """line -> (rule ids, reason); plus AST007 findings for missing reasons."""
    out: dict[int, tuple[set, str]] = {}
    bad: list[tuple[int, str]] = []
    for i, line in enumerate(src.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        reason = (m.group(2) or "").strip()
        if not reason:
            bad.append((i, ", ".join(sorted(rules))))
        out[i] = (rules, reason)
    return out, bad


def _numpy_aliases(tree: ast.Module) -> set[str]:
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    names.add(a.asname or "numpy")
    return names


def _module_np_arrays(tree: ast.Module, np_names: set[str]) -> set[str]:
    """Module-level `NAME = np.array(...)`-style constant tables."""
    out = set()
    for node in tree.body:
        if not isinstance(node, ast.Assign):
            continue
        val = node.value
        if (isinstance(val, ast.Call)
                and isinstance(val.func, ast.Attribute)
                and isinstance(val.func.value, ast.Name)
                and val.func.value.id in np_names):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.add(tgt.id)
    return out


def _takes_tracer(node) -> bool:
    """The repo's traced-function convention: >= 1 param annotated with
    jax.Array (jnp aliases included).  Lambdas and un-annotated helpers
    count as traced when nested inside a traced function (see caller)."""
    args = node.args
    all_args = args.posonlyargs + args.args + args.kwonlyargs
    for a in all_args:
        if a.annotation is None:
            continue
        try:
            txt = ast.unparse(a.annotation)
        except Exception:
            continue
        if "jax.Array" in txt or "jnp.ndarray" in txt:
            return True
    return False


class _FileLint(ast.NodeVisitor):
    def __init__(self, path: str, tree: ast.Module, *, hot: bool,
                 kernel_module: bool, registry_names: frozenset[str]):
        self.path = path
        self.hot = hot
        self.kernel_module = kernel_module
        self.registry = registry_names
        self.np_names = _numpy_aliases(tree)
        self.np_arrays = _module_np_arrays(tree, self.np_names)
        self.findings: list[Finding] = []
        self._fn_depth = 0
        self._prop_depth = 0
        self._traced_stack: list[bool] = []

    def add(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, message=message, file=self.path,
            line=getattr(node, "lineno", 0)))

    # --- function context ----------------------------------------------------
    def _visit_fn(self, node) -> None:
        is_prop = any(
            (isinstance(d, ast.Name) and d.id in ("property",
                                                  "cached_property"))
            or (isinstance(d, ast.Attribute) and d.attr == "cached_property")
            for d in node.decorator_list)
        if self.kernel_module:
            for arg, default in zip(
                    reversed(node.args.args + node.args.kwonlyargs),
                    reversed(node.args.defaults + node.args.kw_defaults)):
                if (arg.arg == "interpret" and default is not None
                        and isinstance(default, ast.Constant)
                        and default.value is not None):
                    self.add("AST005", node,
                             f"`{node.name}` defaults interpret="
                             f"{default.value!r}; kernels must default "
                             "interpret=None (policy.resolve_interpret)")
        self._fn_depth += 1
        self._prop_depth += is_prop
        self._traced_stack.append(_takes_tracer(node))
        self.generic_visit(node)
        self._traced_stack.pop()
        self._prop_depth -= is_prop
        self._fn_depth -= 1

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    # --- calls ---------------------------------------------------------------
    @property
    def _in_traced_body(self) -> bool:
        """Inside a function that takes a jax.Array (or a closure nested in
        one) and is not config-time `@property` math."""
        return (self.hot and any(self._traced_stack)
                and self._prop_depth == 0)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        in_traced_body = self._in_traced_body
        if (in_traced_body and isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id in self.np_names):
            self.add("AST001", node,
                     f"`{f.value.id}.{f.attr}(...)` in a jit-reachable "
                     "function body — use jnp, or hoist to module level")
        if (in_traced_body and isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id == "random"):
            self.add("AST002", node,
                     f"`random.{f.attr}(...)` in a jit-reachable module — "
                     "use jax.random with a threaded key")
        if (isinstance(f, ast.Attribute) and f.attr == "make"
                and isinstance(f.value, ast.Name)
                and f.value.id in ("envs", "registry")
                and node.args and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)
                and self.registry
                and node.args[0].value not in self.registry):
            self.add("AST006", node,
                     f"envs.make({node.args[0].value!r}): not a registered "
                     "scenario name")
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import) -> None:
        if self.hot and any(a.name == "random" for a in node.names):
            self.add("AST002", node, "`import random` in a jit-reachable "
                                     "module — use jax.random")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if self.hot and node.module == "random":
            self.add("AST002", node, "`from random import ...` in a "
                                     "jit-reachable module — use jax.random")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (node.attr == "float64" and isinstance(node.value, ast.Name)
                and node.value.id in ("jnp", "jax")):
            self.add("AST004", node, "jnp.float64 — x64 is never enabled "
                                     "in production")
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        # a float()-wrapped subscript never appears here: the wrap makes
        # the operand a Call node, so a bare Subscript operand is exactly
        # the un-wrapped pattern
        if self._in_traced_body:
            for side in (node.left, node.right):
                if (isinstance(side, ast.Subscript)
                        and isinstance(side.value, ast.Name)
                        and side.value.id in self.np_arrays):
                    self.add("AST003", node,
                             f"`{side.value.id}[...]` used in arithmetic "
                             "without float() wrap — the numpy f64 scalar "
                             "re-promotes the carry dtype")
        self.generic_visit(node)


def _registry_names() -> frozenset[str]:
    try:
        from .. import envs
        return frozenset(envs.registered())
    except Exception:
        return frozenset()


def lint_source(path: str, src: str, *, hot: bool | None = None,
                kernel_module: bool | None = None,
                registry_names: frozenset[str] | None = None
                ) -> list[Finding]:
    """All AST findings for one file (suppressions applied)."""
    rel = path.replace(os.sep, "/")
    if hot is None:
        hot = (any(p in rel for p in HOT_PREFIXES)
               and not any(rel.endswith(e.split("/")[-1]) and e in rel
                           for e in HOT_EXCLUDES))
    if kernel_module is None:
        kernel_module = ("src/repro/kernels/" in rel
                         and not rel.endswith(("policy.py", "_compat.py")))
    tree = ast.parse(src, filename=path)
    lint = _FileLint(path, tree, hot=hot, kernel_module=kernel_module,
                     registry_names=(_registry_names()
                                     if registry_names is None
                                     else registry_names))
    lint.visit(tree)

    supp, missing_reason = _suppressions(src)
    for line, rules in missing_reason:
        lint.findings.append(Finding(
            rule="AST007", file=path, line=line,
            message=f"suppression of {rules} has no ` -- reason`"))
    for f in lint.findings:
        rules, reason = supp.get(f.line, (set(), ""))
        if f.rule in rules and reason:
            f.suppressed, f.suppress_reason = True, reason
    return lint.findings


def iter_python_files(root: str) -> Iterable[str]:
    for base in ("src", "examples", "benchmarks", "tests"):
        top = os.path.join(root, base)
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", "fixtures")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def run(report: Report | None = None, root: str = ".") -> Report:
    report = report or Report()
    names = _registry_names()
    n_files = 0
    for path in iter_python_files(root):
        with open(path) as fh:
            src = fh.read()
        rel = os.path.relpath(path, root)
        report.extend(lint_source(rel, src, registry_names=names))
        n_files += 1
    report.meta.setdefault("ast_rules", {})["files_scanned"] = n_files
    return report

"""Layer-1 kernel auditor: static checks over every Pallas kernel.

Each registered kernel is traced (never executed) at a small but
structurally representative shape, and its `pallas_call` equations are
inspected:

KERN001  the kernel closes over an array constant.  Pallas lowers closure
         constants by materializing them per launch; on TPU this either
         fails outright or silently stages the array through HBM on every
         grid step.  The fix is always the same: pass the array as a real
         input with its own BlockSpec (PR 6's `d_matrix` lesson).
KERN002  a block shape that does not divide its (padded) array dim — the
         callers' `(-n) % block` padding contract was broken, so the last
         grid step reads/writes a partial block.
KERN003  estimated VMEM working set (sum of all input/output blocks)
         above the per-core budget.  An estimate, not a compiler bound —
         it catches the "someone doubled block_e" class of regression
         before a TPU ever sees the kernel.

The registry below pins every kernel entry point in `src/repro/kernels/`;
`tests/test_analysis.py` red-teams each rule with a deliberately bad
kernel.
"""
from __future__ import annotations

from typing import Callable

import jax
from jax import core as jcore

from .report import Finding, Report

# TPU v4/v5 VMEM is ~16 MiB/core; leave headroom for compiler scratch.
VMEM_BUDGET_MB = 12.0


def _kernel_cases() -> dict[str, Callable[[], tuple]]:
    """name -> builder returning (fn, args, kwargs); traced, not run."""
    import jax.numpy as jnp

    def dg_derivative3():
        from ..kernels.dg_derivative import dg_derivative3 as fn
        u = jnp.zeros((4, 4, 4, 4, 5), jnp.float32)
        d = jnp.zeros((4, 4), jnp.float32)
        return fn, (u, d), dict(block_b=2, interpret=True)

    def smagorinsky_nut():
        from ..kernels.smagorinsky import smagorinsky_nut as fn
        g = jnp.zeros((96, 3, 3), jnp.float32)
        cs = jnp.zeros((96,), jnp.float32)
        return fn, (g, cs), dict(delta=0.1, block_p=32, interpret=True)

    def wall_model_tau():
        from ..kernels.wall_model import wall_model_tau as fn
        up = jnp.ones((64,), jnp.float32)
        rw = jnp.ones((64,), jnp.float32)
        return fn, (up, rw), dict(y_m=0.1, nu=1e-3, block_p=32,
                                  interpret=True)

    def fused_rhs():
        from ..cfd.solver import HITConfig
        from ..kernels.rhs import fused_navier_stokes_rhs as fn
        cfg = HITConfig(n_poly=3, n_elem=2, use_kernels=False)
        ops = cfg.operators()
        u = jnp.zeros((2, 2, 2, 4, 4, 4, 5), jnp.float32)
        cs = jnp.zeros((2, 2, 2, 4, 4, 4), jnp.float32)
        return fn, (u, cs, ops["D"], ops["w"]), dict(
            inv_w_end=ops["inv_w_end"], jac=cfg.dg.jac,
            delta=cfg.delta_filter, mu=cfg.gas.mu, prandtl=cfg.prandtl,
            prandtl_turb=cfg.prandtl_turb, forcing_a0=cfg.forcing_a0,
            k_tke=cfg.k_tke, interpret=True)

    def flash_attention():
        from ..kernels.flash_attention import flash_attention as fn
        q = jnp.zeros((1, 2, 64, 16), jnp.float32)
        kv = jnp.zeros((1, 2, 64, 16), jnp.float32)
        return fn, (q, kv, kv), dict(block_q=32, block_k=32,
                                     interpret=True)

    def linear_scan():
        from ..kernels.linear_scan import linear_scan as fn
        x = jnp.zeros((2, 32, 8), jnp.float32)
        v = jnp.zeros((2, 32, 4), jnp.float32)
        return fn, (x, x, v, x), dict(chunk=16, interpret=True)

    return {
        "dg_derivative3": dg_derivative3,
        "smagorinsky_nut": smagorinsky_nut,
        "wall_model_tau": wall_model_tau,
        "fused_rhs": fused_rhs,
        "flash_attention": flash_attention,
        "linear_scan": linear_scan,
    }


def _walk_pallas_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
        for val in eqn.params.values():
            for item in (val if isinstance(val, (list, tuple)) else (val,)):
                if isinstance(item, jcore.ClosedJaxpr):
                    yield from _walk_pallas_eqns(item.jaxpr)
                elif isinstance(item, jcore.Jaxpr):
                    yield from _walk_pallas_eqns(item)


def audit_kernel(name: str, fn, args: tuple, kwargs: dict,
                 vmem_budget_mb: float = VMEM_BUDGET_MB
                 ) -> tuple[list[Finding], dict]:
    """Findings + {'vmem_mb': estimate} for one traced kernel call."""
    findings: list[Finding] = []
    try:
        closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    except ValueError as e:
        # jax raises eagerly at trace time for closure-captured arrays
        # ("Pallas kernel captures constants ... pass them as inputs")
        if "constant" in str(e).lower():
            return [Finding(rule="KERN001", entrypoint=name,
                            message=f"kernel captures array constants "
                                    f"({str(e).splitlines()[0][:140]})")], {}
        raise

    vmem_bytes = 0
    for eqn in _walk_pallas_eqns(closed.jaxpr):
        inner = eqn.params.get("jaxpr")
        const_avals = [v.aval for v in getattr(inner, "constvars", ())]
        big = [a for a in const_avals if getattr(a, "size", 0) > 1]
        if big:
            findings.append(Finding(
                rule="KERN001", entrypoint=name,
                message=f"kernel closes over {len(big)} array constant(s) "
                        f"{[tuple(a.shape) for a in big]} — pass them as "
                        "inputs with BlockSpecs"))
        gm = eqn.params.get("grid_mapping")
        if gm is None:
            continue
        for bm in gm.block_mappings:
            arr = bm.array_shape_dtype
            blk = tuple(d if isinstance(d, int) else 1
                        for d in bm.block_shape)
            vmem_bytes += int(
                __import__("math").prod(blk)) * arr.dtype.itemsize
            for b, n in zip(blk, arr.shape):
                if b and n % b != 0:
                    findings.append(Finding(
                        rule="KERN002", entrypoint=name,
                        message=f"block dim {b} does not divide padded "
                                f"array dim {n} (block {blk} vs array "
                                f"{tuple(arr.shape)})"))
    mb = vmem_bytes / 2**20
    if mb > vmem_budget_mb:
        findings.append(Finding(
            rule="KERN003", entrypoint=name,
            message=f"estimated VMEM working set {mb:.2f} MiB exceeds the "
                    f"{vmem_budget_mb} MiB budget"))
    return findings, {"vmem_mb": round(mb, 4)}


def run(report: Report | None = None) -> Report:
    report = report or Report()
    stats = {}
    for name, build in _kernel_cases().items():
        fn, args, kwargs = build()
        findings, meta = audit_kernel(name, fn, args, kwargs)
        report.extend(findings)
        stats[name] = meta
    report.meta.setdefault("kernel_audit", {})["kernels"] = stats
    return report

"""Layer-1 program auditor: walk closed jaxprs of the hot entry points.

Rules
-----
JAX001  float64 anywhere in the traced program.  x64 is never enabled in
        production; an f64 aval means a weak Python float (or an explicit
        np.float64 table) leaked past the `float()`-wrap convention and
        doubled the HBM traffic of everything downstream.
JAX002  dtype churn inside the declared bf16 interval: a state-sized
        f32 -> bf16 `convert_element_type` inside a scan/while body whose
        producer is an elementwise op.  That shape of convert only appears
        when f32 data (an un-cast operator matrix, a stray f32 constant)
        promoted the bf16 carry mid-loop and the result had to be demoted
        again — a full round trip per RK stage.  Demotes fed by reductions
        or `dot_general` are exempt: XLA accumulates bf16 sums/dots in f32
        on purpose (precision-improving, not churn).
JAX003  host callbacks (`pure_callback`/`io_callback`/`debug_callback`)
        inside a hot jitted program — a device->host sync per step.
JAX004  an entry point that declares donation expectations lowers with
        fewer aliased buffers than declared (donation silently dropped by
        a refactor; XLA only warns in logs nobody reads).
JAX005  un-donated output bytes above the entry's declared budget on a
        donating entry point.

Programs are traced with `jax.make_jaxpr` / `.lower()` only — nothing
executes, so the whole registry audits in seconds on CPU.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax import core as jcore
from jax._src import source_info_util

from .entrypoints import ENTRYPOINTS, Built, EntryPoint
from .report import Finding, Report

# Demote producers that are precision-improving, not churn: XLA upcasts
# f16/bf16 reduction + dot accumulators to f32 internally and hands back
# f32; converting that result down to the carry dtype is the intended
# mixed-precision pattern.
_ACCUMULATING_PRIMS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "dot_general", "conv_general_dilated", "cumsum", "cumlogsumexp",
})

_LOOP_PRIMS = frozenset({"scan", "while"})

_CALLBACK_PRIMS = frozenset({"pure_callback", "io_callback", "debug_callback"})


def _src(eqn) -> tuple[str, int]:
    try:
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return frame.file_name, frame.start_line
    except Exception:
        pass
    return "", 0


def _sub_jaxprs(eqn):
    """All jaxprs nested inside one equation's params."""
    for val in eqn.params.values():
        for item in (val if isinstance(val, (list, tuple)) else (val,)):
            if isinstance(item, jcore.ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, jcore.Jaxpr):
                yield item


def _walk(jaxpr, in_loop: bool = False):
    """Yield (eqn, in_loop, producer_prim_of_first_operand)."""
    producer: dict[int, str] = {}
    for eqn in jaxpr.eqns:
        op = eqn.invars[0] if eqn.invars else None
        op_prim = (producer.get(id(op), "") if isinstance(op, jcore.Var)
                   else "literal")
        yield eqn, in_loop, op_prim
        for v in eqn.outvars:
            producer[id(v)] = eqn.primitive.name
        inner_loop = in_loop or eqn.primitive.name in _LOOP_PRIMS
        for sub in _sub_jaxprs(eqn):
            yield from _walk(sub, inner_loop)


def _is_f64(aval) -> bool:
    return getattr(aval, "dtype", None) == jnp.dtype("float64")


def audit_entry(entry: EntryPoint, built: Built | None = None) -> list[Finding]:
    """All JAX* findings for one entry point (program-layer suppressions
    from `entry.suppress` applied)."""
    built = built or entry.build()
    closed = jax.make_jaxpr(built.fn)(*built.args, **built.kwargs)
    findings: list[Finding] = []

    def add(rule: str, message: str, file: str = "", line: int = 0) -> None:
        reason = entry.suppress.get(rule, "")
        findings.append(Finding(
            rule=rule, message=message, file=file, line=line,
            entrypoint=entry.name, suppressed=bool(reason),
            suppress_reason=reason))

    # --- JAX001 / JAX002 / JAX003: one recursive walk ------------------------
    f64_hits = 0
    for eqn, in_loop, op_prim in _walk(closed.jaxpr):
        if eqn.primitive.name in _CALLBACK_PRIMS:
            file, line = _src(eqn)
            add("JAX003", f"{eqn.primitive.name} inside the jitted program",
                file, line)
        if any(_is_f64(v.aval) for v in eqn.outvars) and f64_hits < 5:
            f64_hits += 1
            file, line = _src(eqn)
            add("JAX001",
                f"float64 result of `{eqn.primitive.name}`", file, line)
        if (built.bf16_interval and in_loop
                and eqn.primitive.name == "convert_element_type"
                and eqn.params.get("new_dtype") == jnp.bfloat16
                and eqn.invars
                and getattr(eqn.invars[0].aval, "dtype", None)
                == jnp.dtype("float32")
                and eqn.invars[0].aval.size >= max(1, built.state_size // 4)
                and op_prim not in _ACCUMULATING_PRIMS):
            file, line = _src(eqn)
            add("JAX002",
                f"state-sized f32->bf16 demote (producer `{op_prim or 'loop carry'}`, "
                f"{eqn.invars[0].aval.size} elems) inside the bf16 interval "
                "— f32 data is promoting the carry mid-loop", file, line)

    # --- JAX004 / JAX005: donation via the lowered StableHLO -----------------
    if built.jit_fn is not None:
        jit_args = built.jit_args if built.jit_args is not None else built.args
        text = built.jit_fn.lower(*jit_args).as_text()
        aliased = {int(m) for m in
                   re.findall(r"tf\.aliasing_output\s*=\s*(\d+)", text)}
        if len(aliased) < built.expect_aliased:
            add("JAX004",
                f"expected >= {built.expect_aliased} donated (aliased) "
                f"buffers in the lowered program, found {len(aliased)}")
        if built.max_undonated_mb is not None:
            out_leaves = jax.tree.leaves(
                jax.eval_shape(built.fn, *built.args, **built.kwargs))
            undonated = sum(
                leaf.size * leaf.dtype.itemsize
                for i, leaf in enumerate(out_leaves) if i not in aliased)
            mb = undonated / 2**20
            if mb > built.max_undonated_mb:
                add("JAX005",
                    f"{mb:.2f} MB of un-donated outputs (budget "
                    f"{built.max_undonated_mb} MB) — donation dropped?")

    return findings


def run(report: Report | None = None,
        names: tuple[str, ...] | None = None) -> Report:
    """Audit every registered entry point (or the named subset)."""
    report = report or Report()
    audited = []
    for entry in ENTRYPOINTS:
        if names and entry.name not in names:
            continue
        report.extend(audit_entry(entry))
        audited.append(entry.name)
    report.meta.setdefault("jaxpr_audit", {})["entrypoints"] = audited
    return report

"""Static analysis of the compiled-program invariants (`repro-lint`).

Two layers over one finding/report currency (`report.py`):

* **Program auditors** inspect traced artifacts of the registered hot
  entry points (`entrypoints.py`): `jaxpr_audit` (f64, bf16-interval
  dtype churn, host callbacks, dropped donation), `trace_audit` (pinned
  compile counts — no silent retraces), `kernel_audit` (Pallas closure
  constants, block divisibility, VMEM budget).
* **Source lint** (`ast_rules`) enforces the repo's jit idioms at the
  AST level (no numpy/`random` in traced code, `float()`-wrapped table
  scalars, no `jnp.float64`, `interpret=None` kernel defaults,
  registry-complete `envs.make` names).

Run via ``repro-lint`` (or ``python -m repro.analysis``); docs in
`docs/static_analysis.md`.
"""
from .report import Finding, Report, RULES  # noqa: F401

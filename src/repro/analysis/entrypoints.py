"""The registry of hot compiled entry points the program auditors walk.

These are the programs whose compiled form IS the product — the per-step
solver advance, the fleet rollout, the PPO/fleet updates, the fused RHS
mega-kernel, and the broker's donated push.  `jaxpr_audit.audit_entry`
traces each one at a reduced (but structurally faithful) shape and checks
the resulting jaxpr/StableHLO against the compiled-program invariants; the
trace auditor re-drives a subset through a reduced training run and pins
compile counts.

Every entry is built lazily (`build()`), at shapes small enough that the
whole registry traces in seconds on CPU.  Audits here never *execute* the
programs — tracing and lowering only.

Program-layer suppressions live on the entry (`suppress={"RULE": reason}`)
so waivers are code-reviewed, not scattered comments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass
class Built:
    """One traced-auditable program: `fn(*args, **kwargs)` must trace."""

    fn: Callable
    args: tuple
    kwargs: dict = dataclasses.field(default_factory=dict)
    # bf16-interval audit (JAX002): the declared mixed-precision interval —
    # inside the advance loop the carried state must stay bf16 (state-sized
    # f32 round trips are churn; reduction-accumulator upcasts are not).
    bf16_interval: bool = False
    state_size: int = 0            # elements of the carried state array
    # donation audit (JAX004/JAX005): lowered aliasing expectations.  Only
    # meaningful when `jit_fn` is the production jit wrapper (donation is a
    # jit-boundary property, not a function property).
    jit_fn: Any = None
    jit_args: tuple | None = None  # call args for jit_fn (defaults to `args`)
    expect_aliased: int = 0        # minimum donated (aliased) input buffers
    max_undonated_mb: float | None = None


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    name: str
    build: Callable[[], Built]
    suppress: dict = dataclasses.field(default_factory=dict)


def _hit_cfg(precision: str = "fp32"):
    from ..cfd.solver import HITConfig
    return HITConfig(n_poly=3, n_elem=2, t_end=0.5, precision=precision,
                     use_kernels=False)


def _build_hit_advance(precision: str) -> Built:
    import jax
    import jax.numpy as jnp

    from ..cfd import initial, solver

    cfg = _hit_cfg(precision)
    u = initial.sample_initial_state(jax.random.PRNGKey(0), cfg)
    cs = jnp.full((cfg.n_elem,) * 3, 0.17, jnp.float32)
    return Built(fn=lambda u, cs: solver.advance_rl_interval(u, cs, cfg),
                 args=(u, cs), bf16_interval=(precision == "bf16"),
                 state_size=u.size)


def _build_channel_advance(precision: str) -> Built:
    import jax
    import jax.numpy as jnp

    from ..cfd import channel as channel_mod
    from ..cfd.channel import ChannelConfig

    cfg = ChannelConfig(n_elem=(2, 3, 2), precision=precision,
                        use_kernels=False)
    u = channel_mod.sample_initial_state(jax.random.PRNGKey(1), cfg)
    kx, _, kz = cfg.n_elem
    scale = jnp.ones((kx, kz), jnp.float32)
    return Built(
        fn=lambda u, sb, st: channel_mod.advance_rl_interval(u, sb, st, cfg),
        args=(u, scale, scale), bf16_interval=(precision == "bf16"),
        state_size=u.size)


def _build_rollout() -> Built:
    import jax

    from .. import envs
    from ..core import policy as policy_lib
    from ..core import rollout as rollout_lib

    env = envs.make("hit_les_reduced")
    pcfg = policy_lib.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
    params = policy_lib.init(jax.random.PRNGKey(0), pcfg)
    u0 = env.initial_state_bank(jax.random.PRNGKey(1), 2)
    key = jax.random.PRNGKey(2)
    return Built(
        fn=lambda params, u0, key: rollout_lib.rollout(
            params, pcfg, env, u0, key),
        args=(params, u0, key))


def _ppo_traj(env, pcfg, params, n_envs: int = 2):
    """A zero trajectory with the exact rollout output structure."""
    import jax
    import jax.numpy as jnp

    from ..core import rollout as rollout_lib

    u0 = env.initial_state_bank(jax.random.PRNGKey(1), n_envs)
    shapes = jax.eval_shape(
        lambda p, u, k: rollout_lib.rollout(p, pcfg, env, u, k),
        params, u0, jax.random.PRNGKey(2))
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def _build_ppo_update() -> Built:
    import jax

    from .. import envs, optim
    from ..core import policy as policy_lib
    from ..core import ppo as ppo_lib

    env = envs.make("hit_les_reduced")
    pcfg = policy_lib.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
    params = policy_lib.init(jax.random.PRNGKey(0), pcfg)
    opt_state = optim.adam_init(params)
    cfg = ppo_lib.PPOConfig()
    traj = _ppo_traj(env, pcfg, params)
    return Built(
        fn=lambda p, o, t: ppo_lib.update(p, o, cfg, pcfg, t),
        args=(params, opt_state, traj))


def _build_fleet_update() -> Built:
    import tempfile

    import jax
    import jax.numpy as jnp

    from ..fleet.pipeline import FleetRunnerConfig, make_fleet_runner

    runner = make_fleet_runner(
        ("hit_les_reduced", "burgers_reduced"), total_envs=2,
        run_cfg=FleetRunnerConfig(
            checkpoint_dir=tempfile.mkdtemp(prefix="repro_audit_"),
            async_checkpoint=False))
    shapes = {name: jax.eval_shape(runner.forch.orchs[name].sample_fleet,
                                   runner.params, jax.random.PRNGKey(0))
              for name in runner.forch.names}
    trajs = {n: jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), t)
             for n, t in shapes.items()}
    return Built(
        fn=lambda p, o, t: runner._update_impl(p, o, t,
                                               jnp.zeros((), jnp.int32)),
        args=(runner.params, runner.opt_state, trajs),
        jit_fn=runner._update,
        jit_args=(runner.params, runner.opt_state, trajs,
                  jnp.zeros((), jnp.int32)),
        # the optimizer state (m, v moments) is donated; params/stats are
        # deliberately NOT (the overlapped rollout still reads params_k)
        expect_aliased=1, max_undonated_mb=8.0)


def _build_fleet_program() -> Built:
    import tempfile

    import jax.numpy as jnp

    from ..fleet.pipeline import FleetRunnerConfig, make_fleet_runner

    runner = make_fleet_runner(
        ("hit_les_reduced", "burgers_reduced"), total_envs=2,
        run_cfg=FleetRunnerConfig(
            checkpoint_dir=tempfile.mkdtemp(prefix="repro_audit_"),
            async_checkpoint=False))
    prog = runner.program
    args = (runner.params, runner.opt_state, runner.broker,
            jnp.zeros((), jnp.int32), runner._keys(1))
    return Built(
        fn=prog._step_impl, args=args,
        jit_fn=prog._step,
        # the optimizer state and the broker rings update in place; params
        # are NOT donated (the guard may keep the old tree, and the audit
        # mirrors the dispatch path's expectations)
        expect_aliased=2, max_undonated_mb=None)


def _build_broker_push() -> Built:
    import jax.numpy as jnp

    from ..fleet import broker as broker_lib

    item = {
        "obs": jnp.zeros((3, 2, 8, 4, 4, 4, 3), jnp.float32),
        "rewards": jnp.zeros((3, 2), jnp.float32),
    }
    ring = broker_lib.ring_init(item, 2)
    return Built(fn=broker_lib.push, args=(ring, item),
                 jit_fn=broker_lib.push_donated,
                 # every ring buffer (and the head counter) updates in place
                 expect_aliased=1, max_undonated_mb=1.0)


def _build_fused_rhs() -> Built:
    import jax
    import jax.numpy as jnp

    from ..cfd import initial
    from ..kernels import rhs as rhs_mod

    cfg = _hit_cfg()
    ops_d = cfg.operators()
    u = initial.sample_initial_state(jax.random.PRNGKey(0), cfg)
    cs = jnp.full(u.shape[:-1], 0.17, u.dtype)
    return Built(
        fn=lambda u, cs: rhs_mod.fused_navier_stokes_rhs(
            u, cs, ops_d["D"], ops_d["w"], inv_w_end=ops_d["inv_w_end"],
            jac=cfg.dg.jac, delta=cfg.delta_filter, mu=cfg.gas.mu,
            prandtl=cfg.prandtl, prandtl_turb=cfg.prandtl_turb,
            forcing_a0=cfg.forcing_a0, k_tke=cfg.k_tke, interpret=True),
        args=(u, cs))


def _build_serve_step() -> Built:
    import jax
    import jax.numpy as jnp

    from .. import envs
    from ..fleet import multitask
    from ..serve import service as serve_lib

    name = "hit_les_reduced"
    mcfg = multitask.MultiTaskConfig.from_envs(
        [(n, envs.make(n)) for n in (name, "burgers_reduced")])
    params = multitask.init(jax.random.PRNGKey(0), mcfg)
    head = mcfg.head(name)
    obs = jnp.zeros((2, head.n_elements, *head.spatial, head.channels),
                    jnp.float32)
    n_valid = jnp.asarray(2, jnp.int32)
    stats = jnp.zeros((2,), jnp.int32)
    svc = serve_lib.ControllerService(params, mcfg)
    return Built(
        fn=lambda p, o, n, s: serve_lib.serve_step(p, mcfg, name, o, n, s),
        args=(params, obs, n_valid, stats),
        jit_fn=svc._step,
        jit_args=(params, mcfg, name, obs, n_valid, stats),
        # the telemetry counter is donated (in-place add per dispatch);
        # actions/values are real outputs and stay small at serving shapes
        expect_aliased=1, max_undonated_mb=1.0)


ENTRYPOINTS: tuple[EntryPoint, ...] = (
    EntryPoint("hit_advance", lambda: _build_hit_advance("fp32")),
    EntryPoint("hit_advance_bf16", lambda: _build_hit_advance("bf16")),
    EntryPoint("channel_advance", lambda: _build_channel_advance("fp32")),
    EntryPoint("channel_advance_bf16",
               lambda: _build_channel_advance("bf16")),
    EntryPoint("rollout", _build_rollout),
    EntryPoint("ppo_update", _build_ppo_update),
    EntryPoint("fleet_update", _build_fleet_update),
    EntryPoint("fleet_program", _build_fleet_program),
    EntryPoint("broker_push", _build_broker_push),
    EntryPoint("fused_rhs", _build_fused_rhs),
    EntryPoint("serve_step", _build_serve_step),
)


def get(name: str) -> EntryPoint:
    for e in ENTRYPOINTS:
        if e.name == name:
            return e
    raise KeyError(f"unknown entry point {name!r}; have "
                   f"{tuple(e.name for e in ENTRYPOINTS)}")

"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use,
and everything else must see the real (single) device.

Topology: TPU v5e, 256 chips/pod (16x16 ICI torus), 2 pods over DCN.
  single pod : (data=16, model=16)
  multi pod  : (pod=2, data=16, model=16)

The `pod` axis is the slow (DCN) axis: only data parallelism (env batches /
LM batches) and gradient reduction cross it (core/compression.py compresses
that hop).  `model` is the fast ICI axis used for tensor/expert/sequence
parallelism.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a (data, model) mesh — tests / examples."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


# Hardware constants for the roofline terms (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip injection)
DCN_BW = 6.25e9                 # bytes/s per chip cross-pod (50 Gb/s)

"""Production mesh construction + the multi-host entry path.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use,
and everything else must see the real (single) device.

Topology: TPU v5e, 256 chips/pod (16x16 ICI torus), 2 pods over DCN.
  single pod : (data=16, model=16)
  multi pod  : (pod=2, data=16, model=16)

The `pod` axis is the slow (DCN) axis: only data parallelism (env batches /
LM batches) and gradient reduction cross it (core/compression.py compresses
that hop).  `model` is the fast ICI axis used for tensor/expert/sequence
parallelism.

Multi-host: `init_distributed` is the guarded `jax.distributed.initialize`
entry (idempotent, env-var driven, no-op for single-process runs) and
`make_fleet_mesh` builds the process-spanning (data, model) mesh from
`jax.devices()` — which enumerates GLOBAL devices once the distributed
runtime is up.  The fleet's single program (`fleet/superbatch.py`) runs
unmodified over that mesh on backends whose runtime supports cross-process
computations (TPU/GPU).  The CPU PJRT backend does not ("Multiprocess
computations aren't implemented on the CPU backend"), so the 2-process CPU
smoke test and the per-host scaling benchmark rows run each process's
LOCAL shard of the collective-free rollout region instead — see
`make_local_mesh` and tests/test_fleet_distributed.py.
"""
from __future__ import annotations

import os

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def _split_data_model(n: int) -> tuple[int, int]:
    """(data, model) factorization of `n` devices: the largest model width
    in {4, 2, 1} that divides evenly; the rest is data parallelism."""
    for model in (4, 2, 1):
        if n % model == 0:
            return n // model, model
    return n, 1


def make_host_mesh():
    """Whatever devices exist, as a (data, model) mesh — tests / examples."""
    data, model = _split_data_model(len(jax.devices()))
    return jax.make_mesh((data, model), ("data", "model"))


def init_distributed(*, coordinator: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None) -> bool:
    """Guarded `jax.distributed.initialize` — the multi-host entry point.

    Reads the standard launcher variables (JAX_COORDINATOR_ADDRESS,
    JAX_NUM_PROCESSES, JAX_PROCESS_ID) when arguments are omitted; returns
    False without touching jax when they are absent (single-process run) or
    when the runtime is already initialized (idempotent re-entry, e.g. a
    benchmark calling through a runner that already initialized).  All
    jax device queries must happen AFTER this returns — `jax.devices()`
    enumerates the global mesh only once the coordinator handshake is done.
    """
    coordinator = coordinator or os.environ.get("JAX_COORDINATOR_ADDRESS")
    if coordinator is None:
        return False
    if num_processes is None:
        num_processes = int(os.environ.get("JAX_NUM_PROCESSES", "1"))
    if process_id is None:
        process_id = int(os.environ.get("JAX_PROCESS_ID", "0"))
    if num_processes <= 1:
        return False
    client = getattr(jax._src.distributed.global_state, "client", None)
    if client is not None:   # already initialized: keep the first init
        return True
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def make_fleet_mesh(*, model: int = 1):
    """Process-spanning (data, model) mesh over ALL devices — every process
    must call this with the same topology (jax.make_mesh uses the global
    device enumeration, identical on every process after
    `init_distributed`).  Data-major by default: the fleet's super-batch
    program shards env batches over `data` only, so every device goes to
    data parallelism unless a model width is requested explicitly."""
    n = len(jax.devices())
    if n % model:
        raise ValueError(f"model={model} does not divide {n} devices")
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_local_mesh(*, model: int = 1):
    """This process's LOCAL devices as a (data, model) mesh — the shard a
    CPU multi-host process runs of the collective-free rollout region
    (cross-process programs need a TPU/GPU runtime; see module docstring).
    """
    local = jax.local_devices()
    if len(local) % model:
        raise ValueError(f"model={model} does not divide {len(local)} "
                         "local devices")
    return Mesh(np.asarray(local).reshape(len(local) // model, model),
                ("data", "model"))


# Hardware constants for the roofline terms (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip injection)
DCN_BW = 6.25e9                 # bytes/s per chip cross-pod (50 Gb/s)

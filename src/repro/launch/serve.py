"""Batched serving driver: prefill a batch of prompts, then decode.

Production shape = the prefill_32k / decode_32k cells (proven by the
dry-run); locally runnable with `--reduced`.  Implements the standard
two-phase server: one prefill program builds the KV caches, a decode
program is stepped autoregressively with donated caches (in-place on
device).  Continuous batching is approximated by slot recycling: finished
sequences (EOS or length) keep decoding but their outputs are masked —
the fleet-level scheduler (out of scope) would swap prompts into slots.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..data import make_batch_for
from ..models import api
from ..parallel import sharding as shd
from . import mesh as mesh_lib, specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = mesh_lib.make_host_mesh()
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    batch = make_batch_for(cfg, args.seed, args.batch, args.prompt_len)
    batch.pop("labels", None)
    cache_len = args.prompt_len + args.gen

    prefill = jax.jit(specs.prefill_fn(cfg, cache_len))
    decode = jax.jit(specs.serve_fn(cfg), donate_argnums=(2,))

    with mesh, shd.axis_rules(mesh):
        t0 = time.perf_counter()
        logits, caches = jax.block_until_ready(prefill(params, batch))
        t_prefill = time.perf_counter() - t0
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            logits, caches = decode(params, tok, caches)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    seqs = np.stack(out, axis=1)  # (B, gen)
    n_prompt_tok = args.batch * args.prompt_len
    n_gen_tok = args.batch * args.gen
    print(f"prefill: {n_prompt_tok} tok in {t_prefill*1e3:.1f} ms "
          f"({n_prompt_tok/t_prefill:,.0f} tok/s)")
    print(f"decode : {n_gen_tok} tok in {t_decode*1e3:.1f} ms "
          f"({n_gen_tok/max(t_decode,1e-9):,.0f} tok/s)")
    print(f"sample completions (token ids): {seqs[:2, :12].tolist()}")


if __name__ == "__main__":
    main()

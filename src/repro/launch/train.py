"""LM training driver.

Production shape: `--arch gemma2-27b --shape train_4k` on the pod mesh (the
dry-run proves those lower/compile); locally runnable shape: `--reduced`
trains the smoke-scale config of the same family on the host devices.

Fault tolerance mirrors core/runner.py: atomic checkpoints carry params,
optimizer, data cursor and RNG; `--resume` restarts from the newest
complete checkpoint (also onto a different device count — elastic).

    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b \
        --reduced --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from .. import configs, optim
from ..core import checkpoints
from ..data import TokenStream
from ..models import api
from ..parallel import sharding as shd
from . import mesh as mesh_lib, specs


def build_train_fn(cfg, mesh, adam_cfg, rule_overrides=None):
    rules = specs.rules_for(mesh, rule_overrides)
    ap, p_sh = specs.param_shardings(cfg, mesh, rules)
    ao, o_sh = specs.opt_shardings(ap, p_sh, mesh)
    fn = jax.jit(specs.train_fn(cfg, adam_cfg),
                 in_shardings=(p_sh, o_sh, None),
                 out_shardings=(p_sh, o_sh, None),
                 donate_argnums=(0, 1))
    return fn, p_sh, o_sh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b", choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="checkpoints/lm")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = mesh_lib.make_host_mesh()
    adam_cfg = optim.AdamConfig(lr=args.lr, grad_clip=1.0)
    train, p_sh, o_sh = build_train_fn(cfg, mesh, adam_cfg)

    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    opt_state = optim.adam_init(params)
    stream = TokenStream(cfg, args.batch, args.seq, seed=args.seed)
    start = 0

    ckpt_dir = os.path.join(args.checkpoint_dir, cfg.name)
    if args.resume:
        step = checkpoints.latest_step(ckpt_dir)
        if step is not None:
            tree, manifest = checkpoints.restore(
                ckpt_dir, step, {"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            stream.load_state_dict(manifest["meta"]["stream"])
            start = int(manifest["meta"]["step"])
            print(f"resumed from step {start}")

    with mesh, shd.axis_rules(mesh):
        for k in range(start, args.steps):
            batch = stream.next()
            t0 = time.perf_counter()
            params, opt_state, metrics = train(params, opt_state, batch)
            metrics = jax.device_get(metrics)
            dt = time.perf_counter() - t0
            tput = args.batch * args.seq / dt
            print(f"step {k:5d} loss={float(metrics['loss']):.4f} "
                  f"grad={float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:8.1f} ms  {tput_str(tput)}", flush=True)
            if (k + 1) % args.checkpoint_every == 0 or k + 1 == args.steps:
                checkpoints.save(
                    ckpt_dir, k + 1,
                    {"params": jax.device_get(params),
                     "opt": jax.device_get(opt_state)},
                    meta={"step": k + 1, "stream": stream.state_dict(),
                          "arch": cfg.name})
    print("done")


def tput_str(tput: float) -> str:
    return f"{tput:,.0f} tok/s"


if __name__ == "__main__":
    main()

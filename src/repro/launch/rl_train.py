"""The paper's training loop: PPO on the HIT LES environment (Relexi).

This is the production entry point for the RL-CFD cells — the TPU-native
equivalent of the paper's `relexi --config ...` SLURM job.  The fleet of
FLEXI-equivalent DGSEM environments shards over the mesh's (pod, data)
axes; the Table-2 Conv3D policy trains with clip-PPO using the paper's
hyperparameters (Sec. 5.3).

    # paper 24-DOF configuration, 16 parallel environments:
    PYTHONPATH=src python -m repro.launch.rl_train --dof 24 --n-envs 16 \
        --iterations 4000
    # CPU-scale smoke:
    PYTHONPATH=src python -m repro.launch.rl_train --reduced --n-envs 2 \
        --iterations 3
"""
from __future__ import annotations

import argparse

import jax

from ..configs import relexi_hit
from ..core.orchestrator import FleetConfig
from ..core.ppo import PPOConfig
from ..core.runner import Runner, RunnerConfig
from . import mesh as mesh_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dof", type=int, choices=(24, 32), default=24)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale HIT config")
    ap.add_argument("--n-envs", type=int, default=16,
                    help="parallel environments (paper: 16/32/64)")
    ap.add_argument("--iterations", type=int, default=100)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--checkpoint-dir", default="checkpoints/relexi")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-mesh", action="store_true")
    args = ap.parse_args()

    if args.reduced:
        env_cfg = relexi_hit.reduced()
    else:
        env_cfg = relexi_hit.HIT24 if args.dof == 24 else relexi_hit.HIT32

    mesh = None if args.no_mesh else mesh_lib.make_host_mesh()
    fleet = FleetConfig(n_envs=args.n_envs,
                        bank_size=max(args.n_envs + 1, 9))
    runner = Runner(
        env_cfg, fleet,
        ppo_cfg=PPOConfig(),  # paper Sec. 5.3 defaults
        run_cfg=RunnerConfig(
            n_iterations=args.iterations,
            eval_every=args.eval_every,
            checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir,
            seed=args.seed,
        ),
        mesh=mesh,
    )
    history = runner.train()
    last = history[-1] if history else {}
    print(f"finished {len(history)} iterations; "
          f"final return={last.get('return_norm', float('nan')):.4f}")


if __name__ == "__main__":
    main()

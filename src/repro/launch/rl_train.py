"""The paper's training loop: PPO on any registered environment (Relexi).

This is the production entry point for the RL-CFD cells — the TPU-native
equivalent of the paper's `relexi --config ...` SLURM job.  The scenario is
selected by registry name (`repro.envs`); the fleet shards over the mesh's
(pod, data) axes and the spec-built policy trains with clip-PPO using the
paper's hyperparameters (Sec. 5.3).

    # paper 24-DOF HIT configuration, 16 parallel environments:
    PYTHONPATH=src python -m repro.launch.rl_train --env hit_les_24dof \
        --n-envs 16 --iterations 4000
    # the 1-D Burgers control scenario, same loop:
    PYTHONPATH=src python -m repro.launch.rl_train --env burgers_96dof
    # CPU-scale smoke:
    PYTHONPATH=src python -m repro.launch.rl_train --reduced --n-envs 2 \
        --iterations 3
"""
from __future__ import annotations

import argparse

import jax

from .. import envs
from ..core.orchestrator import FleetConfig
from ..core.ppo import PPOConfig
from ..core.runner import Runner, RunnerConfig
from . import mesh as mesh_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default=None, choices=envs.registered(),
                    help="registered environment name")
    ap.add_argument("--dof", type=int, choices=(24, 32), default=24,
                    help="HIT Table-1 scale (when --env is not given)")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale HIT config (when --env is not given)")
    ap.add_argument("--n-envs", type=int, default=16,
                    help="parallel environments (paper: 16/32/64)")
    ap.add_argument("--iterations", type=int, default=100)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--checkpoint-dir", default="checkpoints/relexi")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-mesh", action="store_true")
    args = ap.parse_args()

    if args.env:
        name = args.env
    elif args.reduced:
        name = "hit_les_reduced"
    else:
        name = f"hit_les_{args.dof}dof"
    env = envs.make(name)

    mesh = None if args.no_mesh else mesh_lib.make_host_mesh()
    fleet = FleetConfig(n_envs=args.n_envs,
                        bank_size=max(args.n_envs + 1, 9))
    runner = Runner(
        env, fleet,
        ppo_cfg=PPOConfig(),  # paper Sec. 5.3 defaults
        run_cfg=RunnerConfig(
            n_iterations=args.iterations,
            eval_every=args.eval_every,
            checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir,
            seed=args.seed,
        ),
        mesh=mesh,
    )
    print(f"training {name}: {args.iterations} iterations x {args.n_envs} envs")
    history = runner.train()
    last = history[-1] if history else {}
    print(f"finished {len(history)} iterations; "
          f"final return={last.get('return_norm', float('nan')):.4f}")


if __name__ == "__main__":
    main()

"""Roofline-term extraction from compiled XLA artifacts.

`cost_analysis()` supplies HLO FLOPs and HBM bytes; collective bytes are NOT
in cost_analysis, so `collective_bytes` parses the (post-SPMD, per-device)
optimized HLO text and sums, per collective family, the bytes each op moves.

Accounting convention (documented in EXPERIMENTS.md §Roofline): shapes in
the partitioned module are PER-DEVICE; for a ring implementation the bytes
crossing each device's link are ~the op's full (gathered/reduced) buffer:

    all-gather        output size            (each shard passes through)
    reduce-scatter    input  size (= sum of operand sizes)
    all-reduce        2x input size          (reduce-scatter + all-gather)
    all-to-all        input size
    collective-permute input size

The roofline terms (seconds, per step) then follow the brief's formulas with
per-device quantities: term = per_device_bytes / link_bw ==
global_bytes / (chips * link_bw).
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# %name = dtype[d0,d1]{layout} op-name(...)
_DEF_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*\(?([a-z]\w*)\[([\d,]*)\][^ ]*\s+([\w\-]+)\(([^)]*)\)")
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _nbytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def cost_analysis_dict(compiled) -> dict:
    """`compiled.cost_analysis()` normalized to one flat dict.

    jax has flipped this API between a per-program list of dicts and a plain
    dict across versions; every consumer here wants the single-program dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic from optimized HLO text (see module
    docstring for the per-op convention)."""
    # name -> output bytes, for operand lookups
    sizes: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, dtype, dims, op, _ = m.groups()
        sizes[name] = _nbytes(dtype, dims)

    bytes_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _DEF_RE.finditer(hlo_text):
        name, dtype, dims, op, operands = m.groups()
        kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
        if kind is None:
            continue
        # multi-output collectives print a tuple result; fall back to
        # summing operand sizes when the regex saw '(' (bytes==0).
        out_bytes = _nbytes(dtype, dims)
        opnd_bytes = 0
        for ref in operands.split(","):
            ref = ref.strip().lstrip("%")
            ref = ref.split(" ")[-1].lstrip("%")
            opnd_bytes += sizes.get(ref, 0)
        if kind == "all-gather":
            moved = out_bytes or opnd_bytes
        elif kind == "all-reduce":
            moved = 2 * (opnd_bytes or out_bytes)
        elif kind == "reduce-scatter":
            moved = opnd_bytes or out_bytes
        else:  # all-to-all, collective-permute
            moved = opnd_bytes or out_bytes
        bytes_by_kind[kind] += moved
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


def remat_duplication(hlo_text: str) -> dict:
    """Crude remat/redundancy signal: dot-op count and fusion count."""
    return {
        "n_dot": len(re.findall(r"\bdot\(", hlo_text)),
        "n_fusion": len(re.findall(r"\bfusion\(", hlo_text)),
        "n_while": len(re.findall(r"\bwhile\(", hlo_text)),
    }


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   coll_bytes_per_dev: float, n_chips: int,
                   peak_flops: float, hbm_bw: float, link_bw: float,
                   fused_bytes_per_dev: float | None = None) -> dict:
    """The three roofline terms in seconds + the bottleneck label.

    Two memory figures are reported (EXPERIMENTS.md §Roofline):
      memory_raw_s   = cost_analysis "bytes accessed" / HBM_bw — the brief's
                       formula verbatim.  On the CPU backend this counts
                       every op's unfused operand+result I/O and overstates
                       fused-TPU HBM traffic by orders of magnitude.
      memory_s       = (arguments + outputs + 2*temporaries) / HBM_bw — a
                       fused-execution traffic estimate from the compiled
                       buffer assignment; used for bottleneck selection.
    """
    t_compute = flops_per_dev / peak_flops
    t_mem_raw = hbm_bytes_per_dev / hbm_bw
    t_memory = (fused_bytes_per_dev / hbm_bw
                if fused_bytes_per_dev is not None else t_mem_raw)
    t_coll = coll_bytes_per_dev / link_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "memory_raw_s": t_mem_raw, "collective_s": t_coll}
    sel = {"compute_s": t_compute, "memory_s": t_memory,
           "collective_s": t_coll}
    bound = max(sel, key=sel.get)
    terms["bound"] = bound.replace("_s", "")
    # roofline fraction: useful-compute time over the max term (how close the
    # dominant term lets compute run at peak)
    t_max = max(sel.values())
    terms["roofline_fraction"] = float(t_compute / t_max) if t_max > 0 else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train;
    2*N*D for a forward-only cell (prefill), 2*N_active per token for decode.
    D = tokens processed in the cell."""
    n_params = cfg.approx_params()
    if cfg.ffn == "moe":
        d, f = cfg.d_model, cfg.d_ff
        routed_all = cfg.n_experts * 3 * d * f
        routed_active = cfg.top_k * 3 * d * f
        per_layer_delta = routed_all - routed_active
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        n_params = n_params - n_moe_layers * per_layer_delta
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_params * tokens
    if shape.kind == "prefill":
        return 2.0 * n_params * tokens
    return 2.0 * n_params * shape.global_batch  # decode: one token per seq

import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")

"""Multi-pod AOT dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init).  For every cell this script:

    1. builds ShapeDtypeStruct stand-ins for params / optimizer / inputs
       (no allocation — the 35B cells never materialize),
    2. jits the cell program with explicit in/out shardings on the
       production mesh and `.lower().compile()`s it,
    3. records memory_analysis() (proof it fits), cost_analysis() (FLOPs /
       bytes for §Roofline), and the per-device collective traffic parsed
       from the optimized HLO,
    4. writes one JSON artifact per cell under benchmarks/artifacts/dryrun/.

Usage:
    python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
    python -m repro.launch.dryrun --all --mesh single
    python -m repro.launch.dryrun --all --mesh multi
Skipped cells (long_500k on full-attention archs) emit SKIP artifacts with
the reason — they are rows of the roofline table, not silent omissions.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from .. import configs
from ..configs.shapes import SHAPES
from ..models import lm as lm_mod
from . import DRYRUN_ARTIFACT_DIR as ARTIFACT_DIR
from . import hlo_analysis, mesh as mesh_lib, specs


def _memory_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _cost_analysis(compiled) -> dict:
    try:
        ca = hlo_analysis.cost_analysis_dict(compiled)
        return {k: float(v) for k, v in ca.items()
                if k in ("flops", "bytes accessed", "optimal_seconds",
                         "utilization operand")}
    except Exception as e:
        return {"error": str(e)}


def _calibration_cfgs(cfg):
    """(cfg_k1, cfg_k2, K): XLA's cost_analysis counts while-loop bodies
    ONCE, so a scanned layer stack under-reports FLOPs/bytes/collectives by
    ~the trip count.  We therefore lower the SAME cell at 1 and 2 layer
    groups with every sequence/layer scan python-unrolled and extrapolate

        total(K groups) = f(1) + (K - 1) * (f(2) - f(1)).

    Embedding/loss/prefix-layer work lands in the constant term; the
    per-group slope is exact.  (The linear-scan time chunking is capped at
    64 unrolled bodies — <~6% inflation on the tiny SSM/RWKV intra-chunk
    term, noted in EXPERIMENTS.md.)"""
    if cfg.is_encdec:
        # whisper: encoder and decoder stacks both scale with k (4 == 4)
        K = cfg.n_layers
        mk = lambda k: dataclasses.replace(cfg, n_layers=k, encoder_layers=k,
                                           scan_layers=False,
                                           unroll_scans=True)
        return mk(1), mk(2), K
    g = lm_mod.group_size(cfg)
    p = lm_mod.n_prefix(cfg)
    K = lm_mod.n_groups(cfg)
    # large groups (hymba g=8 -> 16 unrolled layers at k=2) need the inner
    # chunk unroll capped harder or the calibration compile takes tens of
    # minutes; ~+5% on the small SSM intra-chunk term (DESIGN.md §5b)
    chunk = max(cfg.scan_chunk, 1024) if g >= 4 else cfg.scan_chunk
    mk = lambda k: dataclasses.replace(cfg, n_layers=p + k * g,
                                       scan_layers=False, unroll_scans=True,
                                       scan_chunk=chunk)
    return mk(1), mk(2), K


def _lowered_costs(cfg, shape, mesh, rule_overrides,
                   opt_rule_overrides=None) -> dict:
    lowered, _ = specs.lower_cell(cfg, shape, mesh, rule_overrides,
                                  donate=False,
                                  opt_rule_overrides=opt_rule_overrides)
    # flop counts and collective shapes are fusion-independent: compile the
    # calibration programs at optimization level 0 (~1.7x faster)
    try:
        compiled = lowered.compile(
            compiler_options={"xla_backend_optimization_level": "0"})
    except Exception:
        compiled = lowered.compile()
    cost = _cost_analysis(compiled)
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": float(coll.total_bytes),
            "coll_by_kind": coll.bytes_by_kind}


def calibrated_costs(cfg, shape, mesh, rule_overrides,
                     opt_rule_overrides=None) -> dict:
    """Scan-corrected per-device flops / HBM bytes / collective bytes."""
    c1_cfg, c2_cfg, K = _calibration_cfgs(cfg)
    f1 = _lowered_costs(c1_cfg, shape, mesh, rule_overrides, opt_rule_overrides)
    f2 = _lowered_costs(c2_cfg, shape, mesh, rule_overrides, opt_rule_overrides)
    out = {}
    for key in ("flops", "bytes", "coll"):
        # clamp the per-group slope at 0: XLA occasionally CSEs collectives
        # harder in the 2-group program, which would extrapolate negative
        out[key] = f1[key] + (K - 1) * max(0.0, f2[key] - f1[key])
    out["coll_by_kind"] = {
        k: f1["coll_by_kind"][k]
        + (K - 1) * max(0, f2["coll_by_kind"][k] - f1["coll_by_kind"][k])
        for k in f1["coll_by_kind"]}
    out["calibration"] = {"K": K, "k1": f1, "k2": f2}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rule_overrides: dict | None = None, *, save: bool = True,
             tag: str = "", calibrate: bool = True,
             cfg_overrides: dict | None = None,
             opt_rule_overrides: dict | None = None) -> dict:
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    n_chips = 512 if multi_pod else 256

    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind, "status": "ok",
              "rules": rule_overrides or {}, "cfg": cfg_overrides or {}}

    for sh, runnable, reason in configs.cells(cfg):
        if sh.name == shape_name and not runnable:
            record.update(status="skip", reason=reason)
            _save(record, tag)
            return record
    if shape.kind == "decode" and cfg.family == "encoder-only":
        record.update(status="skip", reason="encoder-only: no decode step")
        _save(record, tag)
        return record

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    record["opt_rules"] = opt_rule_overrides or {}
    t0 = time.perf_counter()
    try:
        lowered, meta = specs.lower_cell(cfg, shape, mesh, rule_overrides,
                                         opt_rule_overrides=opt_rule_overrides)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = _memory_analysis(compiled)
        cost = _cost_analysis(compiled)
        hlo = compiled.as_text()
        coll = hlo_analysis.collective_bytes(hlo)
        dup = hlo_analysis.remat_duplication(hlo)

        if calibrate:
            cal = calibrated_costs(cfg, shape, mesh, rule_overrides,
                                   opt_rule_overrides)
            flops_dev, hbm_dev, coll_dev = cal["flops"], cal["bytes"], cal["coll"]
            coll_by_kind = cal["coll_by_kind"]
        else:  # raw (while bodies counted once — under-reports scans)
            cal = None
            flops_dev = cost.get("flops", 0.0)
            hbm_dev = cost.get("bytes accessed", 0.0)
            coll_dev = float(coll.total_bytes)
            coll_by_kind = coll.bytes_by_kind
        fused_bytes = None
        if all(k in mem for k in ("argument_size_in_bytes",
                                  "output_size_in_bytes",
                                  "temp_size_in_bytes")):
            fused_bytes = (mem["argument_size_in_bytes"]
                           + mem["output_size_in_bytes"]
                           + 2 * mem["temp_size_in_bytes"])
        terms = hlo_analysis.roofline_terms(
            flops_dev, hbm_dev, coll_dev, n_chips,
            mesh_lib.PEAK_FLOPS_BF16, mesh_lib.HBM_BW, mesh_lib.ICI_BW,
            fused_bytes_per_dev=fused_bytes)
        mf = hlo_analysis.model_flops(cfg, shape)
        record.update({
            "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "memory_analysis": mem,
            "cost_analysis_raw": cost,
            "flops_per_dev": flops_dev,
            "hbm_bytes_per_dev": hbm_dev,
            "collective_bytes_per_dev": coll_by_kind,
            "collective_counts_raw": coll.count_by_kind,
            "collective_total_per_dev": coll_dev,
            "calibration": cal["calibration"] if cal else None,
            "hlo_op_counts": dup,
            "roofline": terms,
            "model_flops_global": mf,
            "model_flops_per_dev": mf / n_chips,
            "useful_flop_ratio": (mf / n_chips) / flops_dev if flops_dev else None,
        })
    except Exception as e:
        record.update(status="fail", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    if save:
        _save(record, tag)
    return record


def _fleet_cell_costs(compiled, c1, c2, K: int, n_chips: int,
                      n_envs: int) -> dict:
    """Shared cost extraction for the RL fleet cells (HIT and channel):
    substep-scan calibration from the 1- and 2-substep compiles
    (cost_analysis counts while bodies once), memory/roofline terms, and
    the per-env step cost (`flops_per_env`) the fleet scheduler consumes
    as its sub-fleet weight (fleet/scheduler.dryrun_step_cost)."""
    def costs(comp):
        cost = _cost_analysis(comp)
        coll = hlo_analysis.collective_bytes(comp.as_text())
        return (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
                float(coll.total_bytes), coll.bytes_by_kind)

    f1, b1, l1, k1 = costs(c1)
    f2, b2, l2, k2 = costs(c2)
    flops = f1 + (K - 1) * (f2 - f1)
    hbm = b1 + (K - 1) * (b2 - b1)
    coll = l1 + (K - 1) * (l2 - l1)
    mem = _memory_analysis(compiled)
    fused = None
    if "temp_size_in_bytes" in mem:
        fused = (mem.get("argument_size_in_bytes", 0)
                 + mem.get("output_size_in_bytes", 0)
                 + 2 * mem["temp_size_in_bytes"])
    terms = hlo_analysis.roofline_terms(
        flops, hbm, coll, n_chips, mesh_lib.PEAK_FLOPS_BF16,
        mesh_lib.HBM_BW, mesh_lib.ICI_BW, fused_bytes_per_dev=fused)
    return {
        "n_substeps": K,
        "n_envs": n_envs,
        "memory_analysis": mem,
        "flops_per_dev": flops,
        "flops_per_env": flops * n_chips / n_envs,
        "hbm_bytes_per_dev": hbm,
        "collective_total_per_dev": coll,
        "collective_bytes_per_dev": {
            key: k1[key] + (K - 1) * (k2[key] - k1[key]) for key in k1},
        "roofline": terms,
    }


def run_relexi_cell(dof: int = 24, n_envs: int = 256, multi_pod: bool = False,
                    *, elem_axis: str | None = "model", tag: str = "",
                    save: bool = True) -> dict:
    """The paper's own cell: one synchronous MDP step of the HIT LES fleet
    (policy eval + Delta t_RL solver advance + reward) on the production
    mesh.  Environments shard over (pod, data) — the paper's weak-scaling
    axis; each environment's element grid shards over `model` — the paper's
    ranks-per-FLEXI strong-scaling axis (halo exchanges lower to
    collective-permute).  The substep scan is calibrated like the LM layer
    scans: lower at 1 and 2 substeps and extrapolate (cost_analysis counts
    while bodies once)."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import relexi_hit
    from ..cfd import env as env_lib, spectra
    from ..core import policy as policy_lib
    from ..parallel import sharding as shd

    env_cfg = relexi_hit.HIT24 if dof == 24 else relexi_hit.HIT32
    if elem_axis:
        # pencil decomposition: the 16-way `model` axis splits into
        # (mx=4, my=4) so the 4x4x4-element grid shards 16 ways — the
        # paper's "16 MPI ranks per FLEXI" strong-scaling point
        shape = (2, 16, 4, 4) if multi_pod else (16, 4, 4)
        axes = (("pod", "data", "mx", "my") if multi_pod
                else ("data", "mx", "my"))
        mesh = jax.make_mesh(shape, axes)
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    mesh_name = "multi" if multi_pod else "single"
    record = {"arch": f"relexi-hit{dof}", "shape": f"fleet_{n_envs}",
              "mesh": mesh_name, "kind": "rl_step", "status": "ok",
              "elem_axis": elem_axis}

    pcfg = policy_lib.PolicyConfig(n_nodes=env_cfg.n_poly + 1,
                                   cs_max=env_cfg.cs_max)
    n = env_cfg.n_poly + 1
    k = env_cfg.n_elem

    def lower_for(cfg_k):
        def mdp_k(params, u, e_dns):
            obs = env_lib.observe(u, cfg_k)
            action = policy_lib.actor_mean(params, pcfg, obs)
            state = env_lib.EnvState(u=u, t_step=jnp.zeros((n_envs,), jnp.int32))
            res = env_lib.step(state, action, cfg_k, e_dns)
            return res.state.u, res.reward

        # paper's two scaling axes: envs over (pod, data) [weak], elements
        # over model [strong].  Without element sharding the fleet claims
        # the model axis for environments too (1 env/chip at 256 envs).
        if elem_axis:
            env_axes = ("pod", "data") if multi_pod else ("data",)
            u_spec = P(env_axes, "mx", "my", None, None, None, None, None)
        else:
            env_axes = ("pod", "data", "model") if multi_pod else (
                "data", "model")
            u_spec = P(env_axes, None, None, None, None, None, None, None)
        with mesh:
            abstract_params = jax.eval_shape(
                lambda: policy_lib.init(jax.random.PRNGKey(0), pcfg))
            u_abs = jax.ShapeDtypeStruct(
                (n_envs, k, k, k, n, n, n, 5), jnp.float32)
            e_abs = jax.ShapeDtypeStruct(
                (len(spectra.reference_spectrum(cfg_k)),), jnp.float32)
            rep = NamedSharding(mesh, P())
            fn = jax.jit(mdp_k, in_shardings=(
                jax.tree.map(lambda _: rep, abstract_params),
                NamedSharding(mesh, u_spec), rep))
            return fn.lower(abstract_params, u_abs, e_abs).compile()

    try:
        t0 = time.perf_counter()
        compiled = lower_for(env_cfg)
        t_compile = time.perf_counter() - t0
        # calibration: 1 and 2 substeps (dt_rl = dt, 2*dt)
        c1 = lower_for(dataclasses.replace(env_cfg, dt_rl=env_cfg.dt * 1.0))
        c2 = lower_for(dataclasses.replace(env_cfg, dt_rl=env_cfg.dt * 2.0))
        record["t_compile_s"] = round(t_compile, 2)
        record.update(_fleet_cell_costs(compiled, c1, c2,
                                        env_cfg.n_substeps, n_chips, n_envs))
    except Exception as e:
        record.update(status="fail", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    if save:
        record["shape"] += f"_{'elem' + str(16) if elem_axis else 'noelem'}"
        _save(record, tag)
    return record


def run_channel_cell(n_envs: int = 256, multi_pod: bool = False, *,
                     variant: str = "channel_wm", tag: str = "",
                     save: bool = True) -> dict:
    """The channel-WMLES fleet cell: one synchronous MDP step (policy eval +
    Delta t_RL wall-modeled solver advance + profile reward) on the
    production mesh — `run_relexi_cell`'s sibling for the channel scenario,
    so its sharding can be sized the same way.

    The channel's element grid is anisotropic (Kx != Ky != Kz) and small
    (3x4x3 by default), so environments shard over ALL mesh axes
    ((pod, data, model)) rather than splitting element space; the substep
    scan is calibrated at 1 and 2 substeps exactly like the HIT cell.

    The artifact carries `flops_per_env` — the per-environment step cost
    the fleet scheduler consumes as its sub-fleet weight
    (`fleet/scheduler.dryrun_step_cost`).
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from .. import envs as envs_mod
    from ..core import policy as policy_lib
    from ..envs.base import EnvState

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    mesh_name = "multi" if multi_pod else "single"
    record = {"arch": "channel-wm", "shape": f"fleet_{n_envs}",
              "mesh": mesh_name, "kind": "rl_step", "status": "ok",
              "variant": variant, "n_envs": n_envs}

    def lower_for(env):
        cfg = env.cfg
        pcfg = policy_lib.PolicyConfig.from_specs(env.obs_spec,
                                                  env.action_spec)

        def mdp(params, u):
            state = EnvState(u=u, t_step=jnp.zeros((n_envs,), jnp.int32))
            action = policy_lib.actor_mean(params, pcfg, env.observe(state))
            res = env.step(state, action)
            return res.state.u, res.reward

        env_axes = ("pod", "data", "model") if multi_pod else ("data",
                                                               "model")
        u_spec = P(env_axes, *([None] * 7))
        with mesh:
            abstract_params = jax.eval_shape(
                lambda: policy_lib.init(jax.random.PRNGKey(0), pcfg))
            kx, ky, kz = cfg.n_elem
            n = cfg.n
            u_abs = jax.ShapeDtypeStruct(
                (n_envs, kx, ky, kz, n, n, n, 5), jnp.float32)
            rep = NamedSharding(mesh, P())
            fn = jax.jit(mdp, in_shardings=(
                jax.tree.map(lambda _: rep, abstract_params),
                NamedSharding(mesh, u_spec)))
            return fn.lower(abstract_params, u_abs).compile()

    try:
        env = envs_mod.make(variant)
        cfg = env.cfg
        t0 = time.perf_counter()
        compiled = lower_for(env)
        t_compile = time.perf_counter() - t0
        # calibration: the same cell at 1 and 2 solver substeps
        c1 = lower_for(envs_mod.make(variant, dt_rl=cfg.dt * 1.0))
        c2 = lower_for(envs_mod.make(variant, dt_rl=cfg.dt * 2.0))
        record["t_compile_s"] = round(t_compile, 2)
        record.update(_fleet_cell_costs(compiled, c1, c2, cfg.n_substeps,
                                        n_chips, n_envs))
    except Exception as e:
        record.update(status="fail", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    if save:
        _save(record, tag)
    return record


def _save(record: dict, tag: str = "") -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(
        ARTIFACT_DIR,
        f"{record['mesh']}_{record['arch']}_{record['shape']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--rules", default="",
                    help='JSON rule overrides, e.g. {"act_seq": null}')
    ap.add_argument("--opt-rules", default="",
                    help="JSON rule overrides for the Adam moments only "
                         "(ZeRO-1-style decoupled optimizer sharding)")
    ap.add_argument("--cfg", default="",
                    help='JSON ArchConfig overrides, e.g. '
                         '{"decode_combine": "flash"}')
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip scan calibration (pass/fail + memory only — "
                         "the multi-pod proof run)")
    ap.add_argument("--relexi", action="store_true",
                    help="run the paper's HIT fleet cell instead of LM cells")
    ap.add_argument("--channel", action="store_true",
                    help="run the channel-WMLES fleet cell (sizes the "
                         "channel sharding; feeds the fleet scheduler)")
    ap.add_argument("--variant", default="channel_wm",
                    help="registered channel scenario for --channel")
    ap.add_argument("--dof", type=int, default=24, choices=(24, 32))
    ap.add_argument("--n-envs", type=int, default=256)
    ap.add_argument("--no-elem-shard", action="store_true")
    args = ap.parse_args()

    if args.channel:
        for multi in {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]:
            rec = run_channel_cell(args.n_envs, multi, variant=args.variant,
                                   tag=args.tag)
            status = rec["status"]
            extra = (f"bound={rec['roofline']['bound']} "
                     f"frac={rec['roofline']['roofline_fraction']:.2f} "
                     f"flops/env={rec['flops_per_env']:.3g}"
                     if status == "ok" else rec.get("error", ""))
            print(f"[{rec['mesh']}] {rec['arch']:24s} {rec['shape']:12s} "
                  f"{status.upper():5s} {extra}", flush=True)
        return

    if args.relexi:
        for multi in {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]:
            rec = run_relexi_cell(
                args.dof, args.n_envs, multi,
                elem_axis=None if args.no_elem_shard else "model",
                tag=args.tag)
            status = rec["status"]
            extra = (f"bound={rec['roofline']['bound']} "
                     f"frac={rec['roofline']['roofline_fraction']:.2f}"
                     if status == "ok" else rec.get("error", ""))
            print(f"[{rec['mesh']}] {rec['arch']:24s} {rec['shape']:12s} "
                  f"{status.upper():5s} {extra}", flush=True)
        return

    overrides = json.loads(args.rules) if args.rules else None
    cfg_overrides = json.loads(args.cfg) if args.cfg else None
    opt_overrides = json.loads(args.opt_rules) if args.opt_rules else None
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = configs.ARCH_NAMES if args.all or not args.arch else [args.arch]
    shapes = tuple(SHAPES) if args.all or not args.shape else [args.shape]

    n_ok = n_skip = n_fail = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                t0 = time.perf_counter()
                rec = run_cell(arch, shape, multi, overrides, tag=args.tag,
                               cfg_overrides=cfg_overrides,
                               calibrate=not args.no_calibrate,
                               opt_rule_overrides=opt_overrides)
                dt = time.perf_counter() - t0
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skip"
                n_fail += status == "fail"
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"bound={r['bound']} "
                             f"frac={r['roofline_fraction']:.2f} "
                             f"compile={rec['t_compile_s']}s")
                elif status == "skip":
                    extra = rec["reason"]
                else:
                    extra = rec["error"]
                print(f"[{'multi' if multi else 'single'}] {arch:24s} "
                      f"{shape:12s} {status.upper():5s} ({dt:5.1f}s) {extra}",
                      flush=True)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip, {n_fail} fail", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

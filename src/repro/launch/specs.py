"""Abstract input specs + shardings for every (arch × shape) dry-run cell.

Everything here is ShapeDtypeStruct-based: no device allocation ever happens
(the 27B/35B cells would not fit host RAM).  The same shardings drive the
real launcher (train.py / serve.py) via `jax.device_put`.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import optim
from ..configs.shapes import ShapeConfig
from ..models import api
from ..models.config import ArchConfig
from ..parallel import sharding as shd


def rules_for(mesh: Mesh, overrides: dict | None = None) -> shd.AxisRules:
    return shd.AxisRules(mesh, overrides)


def _spec_tree(abstract: Any, axes: Any, rules: shd.AxisRules) -> Any:
    return shd.param_specs(abstract, axes, rules)


def _shardings(abstract: Any, axes: Any, mesh: Mesh,
               rules: shd.AxisRules) -> Any:
    specs = _spec_tree(abstract, axes, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def param_shardings(cfg: ArchConfig, mesh: Mesh, rules: shd.AxisRules,
                    abstract_params: Any | None = None):
    ap = abstract_params if abstract_params is not None else api.abstract_params(cfg)
    return ap, _shardings(ap, api.param_axes(cfg), mesh, rules)


def opt_shardings(abstract_params: Any, param_sh: Any, mesh: Mesh,
                  cfg: ArchConfig | None = None,
                  opt_rules: shd.AxisRules | None = None):
    """AdamState(step, m, v): moments mirror the parameter shardings.

    `opt_rules` decouples the moment layout from the parameter layout —
    ZeRO-1-style: replicate (or lightly shard) the parameters for cheap
    forward/backward collectives while the Adam moments stay fully sharded;
    XLA inserts the small update-time reshards automatically."""
    abstract_opt = jax.eval_shape(optim.adam_init, abstract_params)
    rep = NamedSharding(mesh, P())
    if opt_rules is not None and cfg is not None:
        moment_sh = _shardings(abstract_params, api.param_axes(cfg), mesh,
                               opt_rules)
    else:
        moment_sh = param_sh
    return abstract_opt, optim.adam.AdamState(
        step=rep,
        m=jax.tree.map(lambda _, s: s, abstract_opt.m, moment_sh),
        v=jax.tree.map(lambda _, s: s, abstract_opt.v, moment_sh),
    )


def batch_axes(cfg: ArchConfig, kind: str) -> dict:
    """Logical axes of the input batch dict."""
    if kind == "train":
        ax = {"tokens": ("batch", None), "labels": ("batch", None)}
        if cfg.is_encdec:
            ax["frames"] = ("batch", None, None)
        if cfg.vision_dim:
            ax["patches"] = ("batch", None, None)
        return ax
    if kind == "prefill":
        ax = {"tokens": ("batch", None)}
        if cfg.is_encdec:
            ax["frames"] = ("batch", None, None)
        if cfg.vision_dim:
            ax["patches"] = ("batch", None, None)
        return ax
    return {"token": ("batch",)}


def abstract_batch(cfg: ArchConfig, shape: ShapeConfig, kind: str) -> dict:
    """ShapeDtypeStruct batch for the cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    f32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)
    if kind == "train":
        if cfg.is_encdec:
            return {"tokens": i32((b, s)), "labels": i32((b, s)),
                    "frames": f32((b, cfg.max_source_positions, cfg.d_model))}
        if cfg.vision_dim:
            t = s - cfg.vision_tokens
            return {"tokens": i32((b, t)), "labels": i32((b, t)),
                    "patches": f32((b, cfg.vision_tokens, cfg.vision_dim))}
        return {"tokens": i32((b, s)), "labels": i32((b, s))}
    if kind == "prefill":
        if cfg.is_encdec:
            return {"tokens": i32((b, s)),
                    "frames": f32((b, cfg.max_source_positions, cfg.d_model))}
        if cfg.vision_dim:
            return {"tokens": i32((b, s - cfg.vision_tokens)),
                    "patches": f32((b, cfg.vision_tokens, cfg.vision_dim))}
        return {"tokens": i32((b, s))}
    return {"token": i32((b,))}


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, kind: str,
                    mesh: Mesh, rules: shd.AxisRules):
    ab = abstract_batch(cfg, shape, kind)
    return ab, _shardings(ab, batch_axes(cfg, kind), mesh, rules)


def cache_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: shd.AxisRules, dtype=jnp.bfloat16):
    ac = api.abstract_caches(cfg, shape.global_batch, shape.seq_len, dtype)
    return ac, _shardings(ac, api.cache_axes(cfg), mesh, rules)


# --- the three lowerable cell programs ------------------------------------------
def train_fn(cfg: ArchConfig, adam_cfg: optim.AdamConfig | None = None):
    def step(params, opt_state, batch):
        return api.train_step(params, opt_state, batch, cfg, adam_cfg)
    return step


def prefill_fn(cfg: ArchConfig, cache_len: int):
    def run(params, batch):
        return api.prefill(params, cfg, batch, cache_len=cache_len)
    return run


def serve_fn(cfg: ArchConfig):
    def step(params, token, caches):
        return api.serve_step(params, cfg, token, caches)
    return step


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               rule_overrides: dict | None = None,
               donate: bool = True,
               opt_rule_overrides: dict | None = None):
    """Build shardings and `.lower()` the cell's program.  Returns (lowered,
    dict of metadata)."""
    rules = rules_for(mesh, rule_overrides)
    opt_rules = (rules_for(mesh, opt_rule_overrides)
                 if opt_rule_overrides is not None else None)
    with mesh, shd.axis_rules(mesh, rule_overrides):
        ap, p_sh = param_shardings(cfg, mesh, rules)
        if shape.kind == "train":
            ao, o_sh = opt_shardings(ap, p_sh, mesh, cfg, opt_rules)
            ab, b_sh = batch_shardings(cfg, shape, "train", mesh, rules)
            fn = jax.jit(
                train_fn(cfg),
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = fn.lower(ap, ao, ab)
        elif shape.kind == "prefill":
            ab, b_sh = batch_shardings(cfg, shape, "prefill", mesh, rules)
            ac, c_sh = cache_shardings(cfg, shape, mesh, rules)
            fn = jax.jit(
                prefill_fn(cfg, shape.seq_len),
                in_shardings=(p_sh, b_sh),
                out_shardings=(None, c_sh),
            )
            lowered = fn.lower(ap, ab)
        else:  # decode
            ab, b_sh = batch_shardings(cfg, shape, "decode", mesh, rules)
            ac, c_sh = cache_shardings(cfg, shape, mesh, rules)
            fn = jax.jit(
                serve_fn(cfg),
                in_shardings=(p_sh, b_sh["token"], c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,) if donate else (),
            )
            lowered = fn.lower(ap, ab["token"], ac)
    meta = {"arch": cfg.name, "shape": shape.name, "kind": shape.kind,
            "mesh": dict(mesh.shape)}
    return lowered, meta

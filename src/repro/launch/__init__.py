"""Launch layer: production mesh, AOT dry-run, training/serving drivers."""

"""Launch layer: production mesh, AOT dry-run, training/serving drivers."""
import os

# Where the AOT dry-run writes its per-cell JSON artifacts (and where the
# fleet scheduler reads measured step costs back).  Defined here rather
# than in dryrun.py because importing dryrun has an intentional side
# effect — forcing the host platform device count before jax initializes —
# that mere readers of the path must not trigger.
DRYRUN_ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..",
    "benchmarks", "artifacts", "dryrun")

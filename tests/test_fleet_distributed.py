"""Multi-host smoke: 2-process `jax.distributed` on the CPU backend.

What this pins (and what it honestly cannot): `launch/mesh.py`'s guarded
`init_distributed` entry path brings up a 2-process coordinator, every
process sees the GLOBAL device enumeration, and `make_fleet_mesh` spans
both processes.  The CPU PJRT runtime cannot EXECUTE cross-process
programs ("Multiprocess computations aren't implemented on the CPU
backend"), so each process then runs its LOCAL shard of the fleet's
collective-free rollout region (`FleetProgram.rollout_super_batch` over
`make_local_mesh`) — which is exactly the per-host work the full TPU/GPU
program distributes, minus the cross-host stitching the CPU runtime lacks.

Mechanics: the test spawns two fresh subprocesses (the parent process has
long since initialized single-process jax and cannot re-init), pointing
them at a coordinator port bound-and-released on localhost.  Each worker
sets `--xla_force_host_platform_device_count=2` BEFORE importing jax so
the local mesh has a real `data` axis to shard over.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys

import pytest

_WORKER = r"""
import os, sys
coordinator, proc_id = sys.argv[1], sys.argv[2]
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=2")
os.environ["JAX_COORDINATOR_ADDRESS"] = coordinator
os.environ["JAX_NUM_PROCESSES"] = "2"
os.environ["JAX_PROCESS_ID"] = proc_id

import jax
import jax.numpy as jnp
from repro.launch import mesh as mesh_lib

assert mesh_lib.init_distributed(), "guarded init declined a 2-process env"
assert mesh_lib.init_distributed(), "re-entry must be a no-op returning True"
assert jax.process_count() == 2, jax.process_count()
assert len(jax.devices()) == 4, jax.devices()          # 2 procs x 2 local
assert len(jax.local_devices()) == 2, jax.local_devices()

fleet_mesh = mesh_lib.make_fleet_mesh()                 # process-spanning
spanned = {d.process_index for d in fleet_mesh.devices.flat}
assert spanned == {0, 1}, spanned

# local shard of the collective-free rollout region (see module docstring)
from repro import fleet
from repro.fleet.pipeline import FleetRunnerConfig

local = mesh_lib.make_local_mesh()
assert int(local.shape["data"]) == 2
runner = fleet.make_fleet_runner(
    ("burgers_reduced",), total_envs=4, use_artifacts=False,
    mesh=local,
    run_cfg=FleetRunnerConfig(checkpoint_dir=os.environ["SMOKE_TMP"],
                              bank_size=4))
prog = runner.program
keys = runner._keys(0)
out = jax.jit(prog.rollout_super_batch)(runner.params, keys)
traj = out["burgers_reduced"]
assert traj.obs.shape[1] == prog.b_pad["burgers_reduced"] == 4
assert all(bool(jnp.all(jnp.isfinite(x))) for x in
           [traj.obs, traj.actions, traj.rewards, traj.values])
# determinism within the process: same keys -> bit-identical rerun
out2 = jax.jit(prog.rollout_super_batch)(runner.params, keys)
assert all(bool(jnp.array_equal(a, b)) for a, b in
           zip(jax.tree.leaves(out), jax.tree.leaves(out2)))
print(f"proc {proc_id} ok")
"""


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@pytest.mark.slow
def test_two_process_distributed_smoke(tmp_path):
    coordinator = f"127.0.0.1:{_free_port()}"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env["SMOKE_TMP"] = str(tmp_path)
    procs = [subprocess.Popen(
        [sys.executable, "-c", _WORKER, coordinator, str(pid)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
        for pid in range(2)]
    outs = [p.communicate(timeout=600)[0] for p in procs]
    for pid, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"worker {pid} failed:\n{out}"
        assert f"proc {pid} ok" in out

"""PPO / policy tests: GAE closed forms, clip invariants, Table-2 policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import policy as policy_lib, ppo


def _traj(rewards, values, last_value, dones=None):
    t, b = rewards.shape
    dones = jnp.zeros((t, b), bool).at[-1].set(True) if dones is None else dones
    return ppo.Trajectory(
        obs=jnp.zeros((t, b, 1, 2, 2, 2, 3)),
        actions=jnp.zeros((t, b, 1)),
        log_probs=jnp.zeros((t, b)),
        rewards=rewards,
        dones=dones,
        values=values,
        last_value=last_value,
    )


def test_gae_closed_form_three_steps():
    gamma, lam = 0.9, 0.8
    r = jnp.asarray([[1.0], [2.0], [3.0]])
    v = jnp.asarray([[0.5], [0.6], [0.7]])
    traj = _traj(r, v, jnp.asarray([9.9]))  # terminal: last_value unused
    adv, ret = ppo.gae(traj, gamma, lam)
    d2 = 3.0 - 0.7                       # terminal step
    d1 = 2.0 + gamma * 0.7 - 0.6
    d0 = 1.0 + gamma * 0.6 - 0.5
    a2 = d2
    a1 = d1 + gamma * lam * a2
    a0 = d0 + gamma * lam * a1
    np.testing.assert_allclose(np.asarray(adv[:, 0]), [a0, a1, a2], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ret), np.asarray(adv + v), rtol=1e-6)


def test_gae_bootstrap_on_truncation():
    gamma, lam = 0.99, 0.95
    r = jnp.asarray([[1.0]])
    v = jnp.asarray([[2.0]])
    traj = _traj(r, v, jnp.asarray([3.0]), dones=jnp.zeros((1, 1), bool))
    adv, _ = ppo.gae(traj, gamma, lam)
    np.testing.assert_allclose(float(adv[0, 0]), 1.0 + gamma * 3.0 - 2.0,
                               rtol=1e-6)


def test_policy_param_count_matches_table2():
    """Paper Table 2: ~3,300 parameters for the N=5 (n=6) policy."""
    cfg = policy_lib.PolicyConfig(n_nodes=6)
    params = policy_lib.init(jax.random.PRNGKey(0), cfg)
    assert policy_lib.param_count(params) == 3294  # 3,293 conv + log_std


def test_policy_output_dims_table2():
    """Layer plan for n=6 must match Table 2 exactly."""
    assert policy_lib._conv_plan(6) == [
        (3, 8, "SAME"), (3, 8, "VALID"), (3, 4, "VALID"), (2, 1, "VALID")]


def test_policy_action_range():
    cfg = policy_lib.PolicyConfig(n_nodes=4, cs_max=0.5)
    params = policy_lib.init(jax.random.PRNGKey(1), cfg)
    obs = jax.random.normal(jax.random.PRNGKey(2), (3, 8, 4, 4, 4, 3))
    mean = policy_lib.actor_mean(params, cfg, obs)
    assert mean.shape == (3, 8)
    assert bool(jnp.all(mean >= 0.0)) and bool(jnp.all(mean <= 0.5))


def test_log_prob_matches_gaussian():
    mean = jnp.asarray([[0.1, 0.2]])
    std = jnp.asarray([[0.3, 0.3]])
    a = jnp.asarray([[0.0, 0.5]])
    lp = policy_lib.log_prob(mean, std, a)
    want = sum(
        -0.5 * ((ai - mi) / s) ** 2 - np.log(s) - 0.5 * np.log(2 * np.pi)
        for ai, mi, s in [(0.0, 0.1, 0.3), (0.5, 0.2, 0.3)])
    np.testing.assert_allclose(float(lp[0]), want, rtol=1e-5)


def test_ppo_clip_kills_gradient_outside_trust_region():
    """If the ratio is already far outside the clip range and the advantage
    pushes it further out, the surrogate gradient must vanish."""
    cfg = ppo.PPOConfig(clip=0.2)
    adv = jnp.asarray([1.0])  # positive advantage

    def surrogate(delta_logp):
        ratio = jnp.exp(delta_logp)
        clipped = jnp.clip(ratio, 0.8, 1.2)
        return -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))

    g_inside = jax.grad(surrogate)(jnp.asarray(0.0))
    g_outside = jax.grad(surrogate)(jnp.asarray(1.0))  # ratio e >> 1.2
    assert abs(float(g_outside)) < 1e-8
    assert abs(float(g_inside)) > 1e-3


def test_update_improves_surrogate_on_fixed_batch():
    """Five epochs of PPO on one trajectory should increase the likelihood of
    positive-advantage actions (loss decreases)."""
    pcfg = policy_lib.PolicyConfig(n_nodes=4)
    params = policy_lib.init(jax.random.PRNGKey(3), pcfg)
    t, b, e = 4, 3, 8
    key = jax.random.PRNGKey(4)
    obs = jax.random.normal(key, (t, b, e, 4, 4, 4, 3))
    mean, std = policy_lib.distribution(params, pcfg, obs)
    actions = mean + 0.1
    logp = policy_lib.log_prob(mean, std, actions)
    traj = ppo.Trajectory(
        obs=obs, actions=actions, log_probs=logp,
        rewards=jnp.ones((t, b)),
        dones=jnp.zeros((t, b), bool).at[-1].set(True),
        values=policy_lib.value(params, pcfg, obs),
        last_value=jnp.zeros((b,)),
    )
    cfg = ppo.PPOConfig()
    opt = optim.adam_init(params)
    adv, ret = ppo.gae(traj, cfg.gamma, cfg.lam)
    flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                        (traj.obs, traj.actions, traj.log_probs, adv, ret))
    l0 = ppo.ppo_loss(params, cfg, pcfg, *flat)[0]
    new_params, _, stats = ppo.update(params, opt, cfg, pcfg, traj)
    l1 = ppo.ppo_loss(new_params, cfg, pcfg, *flat)[0]
    assert float(l1) < float(l0)
    assert np.isfinite(float(stats["loss"]))


def test_adam_matches_reference_first_step():
    cfg = optim.AdamConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.5])}
    state = optim.adam_init(params)
    new, state = optim.adam_update(cfg, params, grads, state)
    # first step: mhat = g, vhat = g^2 -> delta = g/(|g|+eps) = sign(g)
    np.testing.assert_allclose(np.asarray(new["w"]), [0.9, -2.1], rtol=1e-5)


def test_compressed_psum_int8_error_feedback():
    """int8 psum with error feedback: the residual carries the quantization
    error so the running sum stays unbiased."""
    from repro.core import compression
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    g = {"w": jnp.linspace(-1.0, 1.0, 16)}

    def f(x):
        red, err = compression.compressed_psum(x, "pod", method="int8")
        return red, err

    red, err = shard_map(f, mesh=mesh, in_specs=({"w": P()},),
                         out_specs=({"w": P()}, {"w": P()}))(g)
    np.testing.assert_allclose(np.asarray(red["w"] + err["w"]),
                               np.asarray(g["w"]), atol=1e-6)

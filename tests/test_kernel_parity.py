"""Kernel <-> reference parity gate (`pytest -m kernel_parity -q`).

Every Pallas solver-kernel entry point — the fused `navier_stokes_rhs`
mega-kernel, `dg_derivative3`, `smagorinsky_nut` and `wall_model_tau` — is
swept over a dtype x shape x block-size grid in
interpret mode against its pure-jnp oracle in `kernels/ref.py`, with pinned
per-kernel tolerances; plus full-path regressions proving a complete RHS /
env step with `use_kernels=True` matches the reference assembly.  This gate
is what lets kernels default ON for TPU runs (kernels.default_impl()):
any future kernel edit that drifts from the oracle fails here first.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import channel, solver
from repro.cfd.channel import ChannelConfig
from repro.cfd.solver import HITConfig
from repro.envs import registry
from repro.kernels import ops, ref
from repro.kernels.dg_derivative import dg_derivative3
from repro.kernels.smagorinsky import smagorinsky_nut
from repro.kernels.wall_model import wall_model_tau

pytestmark = pytest.mark.kernel_parity

# Pinned per-kernel tolerances.  float32 paths do the same math in the same
# order (kernels accumulate in f32); bfloat16 tolerances cover the 8-bit
# mantissa of the in/out casts.
TOL = {
    "navier_stokes_rhs_fused": {jnp.float32: dict(rtol=2e-4, atol=2e-4),
                                jnp.bfloat16: dict(rtol=4e-2, atol=4e-2)},
    "dg_derivative3": {jnp.float32: dict(rtol=2e-4, atol=1e-5),
                       jnp.bfloat16: dict(rtol=4e-2, atol=4e-2)},
    "smagorinsky_nut": {jnp.float32: dict(rtol=2e-5, atol=1e-7),
                        jnp.bfloat16: dict(rtol=4e-2, atol=4e-3)},
    "wall_model_tau": {jnp.float32: dict(rtol=1e-5, atol=1e-8),
                       jnp.bfloat16: dict(rtol=4e-2, atol=4e-4)},
}


def _assert_close(kernel_name, dtype, got, want):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **TOL[kernel_name][dtype])


# --- fused Navier-Stokes RHS mega-kernel ------------------------------------
def _synthetic_state(key, shape_prefix, cfg):
    """Physically plausible conservative state: rho ~ 1, subsonic velocity,
    pressure well clear of vacuum — keeps sqrt/temperature paths benign."""
    n = cfg.n_poly + 1
    k = cfg.n_elem
    mesh = shape_prefix + (k, k, k, n, n, n)
    kr, kv, kp = jax.random.split(key, 3)
    rho = 1.0 + 0.1 * jax.random.uniform(kr, mesh + (1,))
    vel = 0.3 * jax.random.normal(kv, mesh + (3,))
    p = 7.0 + 0.5 * jax.random.uniform(kp, mesh + (1,))
    e = p / 0.4 + 0.5 * rho * jnp.sum(vel**2, axis=-1, keepdims=True)
    return jnp.concatenate([rho, rho * vel, e], axis=-1)


def _fused_rhs_kwargs(cfg):
    ops_d = cfg.operators()
    return ops_d, dict(inv_w_end=ops_d["inv_w_end"], jac=cfg.dg.jac,
                       delta=cfg.delta_filter, mu=cfg.gas.mu,
                       prandtl=cfg.prandtl, prandtl_turb=cfg.prandtl_turb,
                       forcing_a0=cfg.forcing_a0, k_tke=cfg.k_tke)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("prefix,n_poly,n_elem,block_e", [
    ((), 3, 2, 1),      # single mesh, production-reduced polynomial order
    ((3,), 3, 2, 2),    # batch with padding (3 % 2 != 0)
    ((4,), 2, 3, 4),    # K=3 periodic exchange, whole batch in one block
])
def test_fused_rhs_parity(prefix, n_poly, n_elem, block_e, dtype):
    from repro.kernels.rhs import fused_navier_stokes_rhs

    cfg = HITConfig(n_poly=n_poly, n_elem=n_elem, use_kernels=False)
    ops_d, kw = _fused_rhs_kwargs(cfg)
    u = _synthetic_state(jax.random.PRNGKey(3), prefix, cfg).astype(dtype)
    cs = jnp.full(u.shape[:-1], 0.17, dtype)
    got = fused_navier_stokes_rhs(u, cs, ops_d["D"], ops_d["w"],
                                  block_e=block_e, interpret=True, **kw)
    want = ref.navier_stokes_rhs_fused(u, cs, ops_d["D"], ops_d["w"], **kw)
    assert got.shape == u.shape and got.dtype == u.dtype
    _assert_close("navier_stokes_rhs_fused", dtype, got, want)


def test_fused_rhs_oracle_matches_solver_assembly():
    """The self-contained `ref.navier_stokes_rhs_fused` oracle reproduces the
    stage-by-stage solver assembly bit-for-bit (same ops, same order) — the
    anchor that ties the mega-kernel's parity gate back to the physics."""
    from repro.cfd import initial

    cfg = HITConfig(n_poly=3, n_elem=2, use_kernels=False)
    ops_d, kw = _fused_rhs_kwargs(cfg)
    u = initial.sample_initial_state(jax.random.PRNGKey(4), cfg)
    cs = jnp.full(u.shape[:-1], 0.17, u.dtype)
    want = solver.navier_stokes_rhs(u, cs, cfg, ops_d)
    got = ref.navier_stokes_rhs_fused(u, cs, ops_d["D"], ops_d["w"], **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --- dg_derivative3 ---------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,c,b,block_b", [
    (4, 5, 16, 8),    # even split
    (6, 3, 10, 4),    # padding (10 % 4 != 0)
    (8, 1, 7, 16),    # block larger than batch
    (4, 4, 27, 9),    # K^3 element batch, odd block
])
def test_dg_derivative3_parity(n, c, b, block_b, dtype):
    u = jax.random.normal(jax.random.PRNGKey(5), (b, n, n, n, c), dtype)
    d = jax.random.normal(jax.random.PRNGKey(6), (n, n), jnp.float32)
    outs = dg_derivative3(u, d, block_b=block_b, interpret=True)
    wants = ref.dg_derivative3(u, d)
    assert all(o.dtype == u.dtype for o in outs)
    for got, want in zip(outs, wants):
        _assert_close("dg_derivative3", dtype, got, want)


# --- smagorinsky_nut --------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("p,block_p", [
    (17, 8),       # padding
    (2048, 512),   # even multi-block
    (64, 128),     # block larger than batch
])
def test_smagorinsky_parity(p, block_p, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    grad_v = jax.random.normal(ks[0], (p, 3, 3), dtype)
    cs = jax.random.uniform(ks[1], (p,), minval=0.0, maxval=0.5).astype(dtype)
    got = smagorinsky_nut(grad_v, cs, 0.1, block_p=block_p, interpret=True)
    want = ref.smagorinsky_nut(grad_v, cs, 0.1)
    assert got.dtype == grad_v.dtype
    _assert_close("smagorinsky_nut", dtype, got, want)


# --- wall_model_tau ---------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,block_p", [
    ((64,), 32),         # flat even split
    ((2, 24, 16), 128),  # (B, n_wall_elems, face_dofs) batch, padding
    ((7,), 64),          # tiny odd batch, block larger than batch
])
def test_wall_model_parity(shape, block_p, dtype):
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    # u_par spans the viscous sublayer through the log layer
    u_par = jax.random.uniform(ks[0], shape, minval=1e-3,
                               maxval=3.0).astype(dtype)
    rho_w = jax.random.uniform(ks[1], shape, minval=0.8,
                               maxval=1.2).astype(dtype)
    kw = dict(y_m=0.05, nu=5e-3, kappa=0.41, iters=8)
    got = wall_model_tau(u_par, rho_w, block_p=block_p, interpret=True, **kw)
    want = ref.wall_model_tau(u_par, rho_w, **kw)
    assert got.shape == shape and got.dtype == u_par.dtype
    _assert_close("wall_model_tau", dtype, got, want)


def test_wall_model_ops_dispatch_matches_ref():
    """The ops-layer dispatch ("kernel" forced, off-TPU interpret) and "ref"
    agree — the exact switch ChannelConfig.kernels_enabled flips."""
    u_par = jnp.linspace(1e-3, 2.0, 37)
    rho = jnp.ones_like(u_par)
    kw = dict(y_m=0.1, nu=1e-3, iters=8)
    got = ops.wall_model_tau(u_par, rho, impl="kernel", **kw)
    want = ops.wall_model_tau(u_par, rho, impl="ref", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL["wall_model_tau"][jnp.float32])


# --- full-path regressions --------------------------------------------------
def test_hit_rhs_kernel_path_matches_reference():
    """Complete HIT RHS with use_kernels forced on (interpret mode off-TPU)
    vs the pure-jnp assembly."""
    from repro.cfd import initial

    cfg_ref = HITConfig(n_poly=3, n_elem=2, use_kernels=False)
    cfg_ker = dataclasses.replace(cfg_ref, use_kernels=True)
    u = initial.sample_initial_state(jax.random.PRNGKey(0), cfg_ref)
    cs = jnp.full(u.shape[:-1], 0.17, u.dtype)
    r_ref = solver.navier_stokes_rhs(u, cs, cfg_ref, cfg_ref.operators())
    r_ker = solver.navier_stokes_rhs(u, cs, cfg_ker, cfg_ker.operators())
    np.testing.assert_allclose(np.asarray(r_ker), np.asarray(r_ref),
                               rtol=2e-4, atol=2e-4)


def test_channel_rhs_kernel_path_matches_reference():
    """Complete wall-BC channel RHS through all three kernels (volume
    derivative, eddy viscosity, wall-model inversion) vs the reference."""
    cfg_ref = ChannelConfig(n_elem=(2, 3, 2), use_kernels=False)
    cfg_ker = dataclasses.replace(cfg_ref, use_kernels=True)
    u = channel.sample_initial_state(jax.random.PRNGKey(1), cfg_ref)
    kx, _, kz = cfg_ref.n_elem
    n = cfg_ref.n
    scale = jnp.broadcast_to(jnp.float32(1.3), (kx, kz, n, n))
    r_ref = channel.channel_rhs(u, scale, scale, cfg_ref, cfg_ref.operators())
    r_ker = channel.channel_rhs(u, scale, scale, cfg_ker, cfg_ker.operators())
    np.testing.assert_allclose(np.asarray(r_ker), np.asarray(r_ref),
                               rtol=2e-4, atol=2e-4)


def test_hit_env_step_kernel_parity():
    """Full `hit_les_reduced` env transition with use_kernels=True (fused
    RHS mega-kernel, interpret off-TPU) matches the reference path."""
    env_ref = registry.make("hit_les_reduced", use_kernels=False)
    env_ker = registry.make("hit_les_reduced", use_kernels=True)
    bank = env_ref.initial_state_bank(jax.random.PRNGKey(9), 1)
    state, obs0 = env_ref.reset_from_bank(bank, jnp.int32(0))
    action = jnp.full((env_ref.action_spec.n_elements,), 0.17, jnp.float32)
    res_ref = env_ref.step(state, action)
    res_ker = env_ker.step(state, action)
    np.testing.assert_allclose(np.asarray(res_ker.state.u),
                               np.asarray(res_ref.state.u),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res_ker.obs),
                               np.asarray(res_ref.obs),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(res_ker.reward), float(res_ref.reward),
                               atol=1e-4)
    assert bool(res_ker.done) == bool(res_ref.done)


def test_channel_env_step_kernel_parity():
    """Full `channel_wm` env transition (one RL interval: n_substeps x 5 RK
    stages, obs + reward) with use_kernels=True matches the reference path
    within float32 tolerance — the acceptance gate for default-on kernels."""
    env_ref = registry.make("channel_wm_reduced", use_kernels=False)
    env_ker = registry.make("channel_wm_reduced", use_kernels=True)
    bank = env_ref.initial_state_bank(jax.random.PRNGKey(2), 1)
    state, obs0 = env_ref.reset_from_bank(bank, jnp.int32(0))
    action = jnp.full((env_ref.action_spec.n_elements,), 1.2, jnp.float32)
    res_ref = env_ref.step(state, action)
    res_ker = env_ker.step(state, action)
    np.testing.assert_allclose(np.asarray(res_ker.state.u),
                               np.asarray(res_ref.state.u),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res_ker.obs),
                               np.asarray(res_ref.obs),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(res_ker.reward), float(res_ref.reward),
                               atol=1e-4)
    assert bool(res_ker.done) == bool(res_ref.done)


# --- REPRO_KERNELS env override ---------------------------------------------
def test_repro_kernels_env_override(monkeypatch):
    """The env var retargets only the *auto* resolution: default_impl() and
    resolve_use_kernels(None) follow it, explicit choices still win."""
    from repro.kernels import policy

    monkeypatch.setenv("REPRO_KERNELS", "kernel")
    assert policy.default_impl() == "kernel"
    assert policy.resolve_use_kernels(None) is True
    assert policy.resolve_use_kernels(False) is False

    monkeypatch.setenv("REPRO_KERNELS", "ref")
    assert policy.default_impl() == "ref"
    assert policy.resolve_use_kernels(None) is False
    assert policy.resolve_use_kernels(True) is True

    backend_default = "kernel" if jax.default_backend() == "tpu" else "ref"
    for val in ("auto", ""):
        monkeypatch.setenv("REPRO_KERNELS", val)
        assert policy.default_impl() == backend_default

    monkeypatch.setenv("REPRO_KERNELS", "bogus")
    with pytest.raises(ValueError, match="REPRO_KERNELS"):
        policy.default_impl()

"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Skip (not crash) the whole module when hypothesis isn't installed, so the
# rest of the suite still collects and runs.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.cfd import spectra
from repro.core import ppo
from repro.kernels import ref
from repro.parallel import sharding as shd

_settings = settings(max_examples=25, deadline=None)


@_settings
@given(st.floats(0.0, 50.0), st.floats(0.05, 2.0))
def test_reward_bounded_and_monotone(ell, alpha):
    r = float(spectra.reward_from_error(jnp.asarray(ell), alpha))
    assert -1.0 <= r <= 1.0
    r2 = float(spectra.reward_from_error(jnp.asarray(ell + 0.1), alpha))
    assert r2 <= r + 1e-9  # lower spectral error is never worse


@_settings
@given(st.floats(0.5, 8.0), st.floats(8.0, 64.0), st.floats(0.2, 3.0))
def test_vkp_spectrum_positive_and_normalized(k_peak, k_eta, u_rms):
    k = np.arange(32)
    e = spectra.vkp_spectrum(k, u_rms, k_peak, k_eta)
    assert np.all(e >= 0.0) and e[0] == 0.0
    np.testing.assert_allclose(e.sum(), 1.5 * u_rms**2, rtol=1e-10)


@_settings
@given(st.integers(1, 6), st.integers(1, 4), st.floats(0.8, 1.0),
       st.floats(0.8, 1.0))
def test_gae_of_zero_rewards_zero_values_is_zero(t, b, gamma, lam):
    traj_r = jnp.zeros((t, b))
    traj_v = jnp.zeros((t, b))
    traj = ppo.Trajectory(
        obs=jnp.zeros((t, b, 1, 2, 2, 2, 3)), actions=jnp.zeros((t, b, 1)),
        log_probs=jnp.zeros((t, b)), rewards=traj_r,
        dones=jnp.zeros((t, b), bool).at[-1].set(True),
        values=traj_v, last_value=jnp.zeros((b,)))
    adv, ret = ppo.gae(traj, gamma, lam)
    np.testing.assert_allclose(np.asarray(adv), 0.0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(ret), 0.0, atol=1e-7)


@_settings
@given(st.integers(1, 32), st.integers(1, 17), st.integers(1, 8))
def test_logical_to_spec_never_breaks_divisibility(d0, d1, d2):
    mesh = jax.make_mesh((1,), ("model",))
    rules = shd.AxisRules(mesh, {"a": "model", "b": "model", "c": None})
    spec = shd.logical_to_spec((d0, d1, d2), ("a", "b", "c"), rules)
    assert len(spec) == 3
    for dim, s in zip((d0, d1, d2), spec):
        if s is not None:
            assert dim % mesh.shape[s if isinstance(s, str) else s[0]] == 0


def test_logical_to_spec_drops_consumed_axes():
    mesh = jax.make_mesh((1,), ("model",))
    rules = shd.AxisRules(mesh, {"a": "model", "b": "model"})
    spec = shd.logical_to_spec((4, 4), ("a", "b"), rules)
    # the second dim must not reuse the axis the first consumed
    named = [s for s in spec if s is not None]
    assert len(named) <= 1


@_settings
@given(st.integers(2, 24), st.integers(1, 3),
       st.floats(0.55, 0.999), st.booleans())
def test_linear_scan_decay_contracts_state(t, b, w_val, dbr):
    """With k=0 inputs the state must decay monotonically (|S| shrinking) —
    the stability property the chunked kernel relies on."""
    dk, dv = 4, 4
    q = jnp.zeros((b, t, dk))
    k = jnp.zeros((b, t, dk))
    v = jnp.zeros((b, t, dv))
    w = jnp.full((b, t, dk), w_val)
    s0 = jnp.ones((b, dk, dv))
    o, s = ref.linear_scan_chunked(q, k, v, w, None, s0,
                                   decay_before_read=dbr, chunk=8)
    np.testing.assert_allclose(np.asarray(s), w_val**t, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(o), 0.0, atol=1e-7)


@_settings
@given(st.floats(1e-3, 3.0), st.floats(1e-3, 2.9), st.floats(0.01, 0.3),
       st.floats(1e-4, 1e-2))
def test_wall_model_tau_monotone_in_matching_velocity(u1, du, y_m, nu):
    """tau_w from the Reichardt inversion must increase with the
    matching-point velocity — faster outer flow never lowers the modeled
    wall friction (the sign the RL action relies on)."""
    rho = jnp.ones(())
    kw = dict(y_m=y_m, nu=nu, iters=8)
    t1 = float(ref.wall_model_tau(jnp.asarray(u1), rho, **kw))
    t2 = float(ref.wall_model_tau(jnp.asarray(u1 + du), rho, **kw))
    assert t2 >= t1 * (1.0 - 1e-6)
    assert t1 > 0.0


@_settings
@given(st.floats(1e-3, 3.0), st.floats(0.01, 0.3), st.floats(1e-4, 1e-2))
def test_wall_model_fixed_point_converges_within_budget(u_par, y_m, nu):
    """The damped fixed point must be converged at the production iteration
    budget: doubling `iters` moves tau_w by < 1%, and the converged u_tau
    satisfies the wall law u_par/u_tau = u+(y_m u_tau / nu)."""
    rho = jnp.ones(())
    t8 = float(ref.wall_model_tau(jnp.asarray(u_par), rho, y_m=y_m, nu=nu,
                                  iters=8))
    t16 = float(ref.wall_model_tau(jnp.asarray(u_par), rho, y_m=y_m, nu=nu,
                                   iters=16))
    assert abs(t16 - t8) <= 1e-2 * abs(t16) + 1e-10
    u_tau = np.sqrt(t16)  # rho = 1
    u_plus = float(ref.reichardt_uplus(jnp.asarray(y_m * u_tau / nu)))
    np.testing.assert_allclose(u_par / u_tau, u_plus, rtol=2e-2)


@_settings
@given(st.floats(0.0, 2.0))
def test_wall_flux_affine_in_action_scale(a):
    """The wall flux is affine in the action: the advective (pressure) part
    is a-independent and the modeled viscous stress scales linearly, so
    f(a) = f(0) + a * (f(1) - f(0)) — in particular a=1 recovers the
    unscaled equilibrium wall model exactly."""
    from repro.cfd import channel
    from repro.cfd.channel import ChannelConfig

    cfg = ChannelConfig(n_elem=(2, 3, 2))
    ops_ch = cfg.operators()
    u = channel.sample_initial_state(jax.random.PRNGKey(11), cfg)
    kx, _, kz = cfg.n_elem
    n = cfg.n

    def fluxes(scale):
        s = jnp.full((kx, kz, n, n), scale, jnp.float32)
        lo, hi = channel.wall_fluxes(u, s, s, cfg, ops_ch)
        return np.asarray(lo), np.asarray(hi)

    f0, f1, fa = fluxes(0.0), fluxes(1.0), fluxes(float(a))
    for lo_hi in range(2):
        want = f0[lo_hi] + a * (f1[lo_hi] - f0[lo_hi])
        np.testing.assert_allclose(fa[lo_hi], want, rtol=1e-5, atol=1e-7)


@_settings
@given(st.integers(0, 1000), st.integers(1, 64), st.integers(1, 64))
def test_ring_buffer_slot_positions_valid(pos, length, _unused):
    """Every warm ring-buffer slot holds a position in (pos-L, pos]."""
    slots = np.arange(length)
    abs_pos = pos - np.mod(pos - slots, length)
    assert np.all(abs_pos <= pos)
    assert np.all(abs_pos > pos - length)


@_settings
@given(st.data())
def test_mha_chunked_equals_naive(data):
    b = data.draw(st.integers(1, 2))
    h = data.draw(st.sampled_from([1, 2, 4]))
    hkv = data.draw(st.sampled_from([x for x in (1, 2, 4) if h % x == 0]))
    sq = data.draw(st.integers(1, 24))
    skv = data.draw(st.integers(sq, 32))
    d = data.draw(st.sampled_from([4, 8]))
    block = data.draw(st.sampled_from([4, 8, 16]))
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2**30)))
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d))
    k = jax.random.normal(ks[1], (b, hkv, skv, d))
    v = jax.random.normal(ks[2], (b, hkv, skv, d))
    a = ref.mha_chunked(q, k, v, causal=True, block_k=block)
    want = ref.mha(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@_settings
@given(st.integers(1, 100), st.integers(1, 8), st.floats(1.0, 2.0))
def test_moe_capacity_is_sufficient_and_aligned(group, topk, cf):
    from repro.models import moe
    from repro.models.config import ArchConfig
    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=8,
                     n_heads=1, kv_heads=1, d_ff=8, vocab=8, n_experts=8,
                     top_k=topk, moe_capacity_factor=cf)
    cap = moe._capacity(group, cfg)
    assert cap % 8 == 0 and cap >= 8
    assert cap * cfg.n_experts >= group * topk * min(cf, 1.0) * 0.99


def test_config_validation_all_archs():
    """Every assigned config satisfies its own structural invariants."""
    from repro import configs
    from repro.models import lm
    for name in configs.ARCH_NAMES:
        cfg = configs.get(name)
        assert cfg.n_heads % cfg.kv_heads == 0, name
        if not cfg.is_encdec:
            lm.n_groups(cfg)  # raises if the scan grouping doesn't divide
        if cfg.ffn == "moe":
            assert 0 < cfg.top_k <= cfg.n_experts
        if cfg.mixer == "attn+mamba":
            assert cfg.ssm_state > 0
        assert cfg.approx_params() > 0

"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU asserting output shapes + finite values — as required by the brief.
Also the strongest correctness test we have: decode-path logits must match
the teacher-forced training-path logits position by position (exercises KV
ring buffers, SSM/RWKV state carries, RoPE offsets and cache masks)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.data import make_batch_for
from repro.models import api, lm

ARCHS = list(configs.ARCH_NAMES)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg = configs.get_reduced(arch)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch_for(cfg, 0, 2, 64)
    opt = optim.adam_init(params)
    p2, o2, metrics = jax.jit(
        lambda p, o, b: api.train_step(p, o, b, cfg))(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["tokens"]) > 0
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))
    # structure preserved
    assert jax.tree.structure(params) == jax.tree.structure(p2)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_axes_mirror_params(arch):
    cfg = configs.get_reduced(arch)
    ap = api.abstract_params(cfg)
    ax = api.param_axes(cfg)
    is_ax = lambda x: x is None or (isinstance(x, tuple) and all(
        isinstance(s, str) or s is None for s in x))
    import jax.tree_util as jtu
    flat_p = jax.tree.leaves(ap)
    flat_a = jtu.tree_leaves(ax, is_leaf=is_ax)
    assert len(flat_p) == len(flat_a)
    # every named axis tuple has the right rank
    flat_p2, _ = jtu.tree_flatten(ap)
    for p, a in zip(flat_p2, jtu.tree_leaves(ax, is_leaf=is_ax)):
        if isinstance(a, tuple):
            assert len(a) == p.ndim, (a, p.shape)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "gemma2-27b",
                                  "rwkv6-1.6b", "hymba-1.5b",
                                  "deepseek-moe-16b", "whisper-tiny"])
def test_decode_matches_teacher_forcing(arch):
    """prefill+decode logits == train-mode logits at every position."""
    cfg = dataclasses.replace(configs.get_reduced(arch), dtype="float32",
                              remat=False)
    if cfg.window:
        cfg = dataclasses.replace(cfg, window=6)  # exercise the ring buffer
    if cfg.ffn == "moe":
        # capacity dropping is dispatch-group dependent (train groups the
        # whole batch, decode routes one token) — equality holds only in the
        # no-drop regime, so give the test full capacity.
        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=float(cfg.n_experts) / cfg.top_k)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, s, prompt = 2, 12, 5
    batch = make_batch_for(cfg, 0, b, s)
    tokens = batch["tokens"]

    # training-path logits over the whole sequence
    if cfg.is_encdec:
        from repro.models import encdec, blocks
        enc = encdec.encode(params, cfg, batch["frames"])
        cross = jax.vmap(lambda p_l: encdec._cross_kv(p_l["xattn"], cfg, enc))(
            params["decoder"])
        kind = encdec._kind(cfg)
        x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.dtype)
        x = x + params["dec_pos"]["table"][:s][None].astype(cfg.dtype)

        def body(x, scanned):
            p_l, cross_l = scanned
            x, _ = encdec._decoder_block(p_l, cfg, kind, x, "train",
                                         {"self": None, "cross": cross_l})
            return x, None

        x, _ = jax.lax.scan(body, x, (params["decoder"], cross))
        h = blocks.apply_norm(params["final_norm"], cfg, x)
        w = params["embed"]["table"].T.astype(h.dtype)
        train_logits = (h @ w).astype(jnp.float32)
    else:
        x = lm.embed_tokens(params, cfg, tokens)
        hidden, _, _ = lm.forward_hidden(params, cfg, x, mode="train")
        train_logits = lm.logits_for(params, cfg, hidden)

    # serving-path logits: prefill the prompt, then teacher-forced decode
    pf_batch = {k: v for k, v in batch.items() if k != "labels"}
    pf_batch["tokens"] = tokens[:, :prompt]
    logits, caches = api.prefill(params, cfg, pf_batch, cache_len=s,
                                 cache_dtype=jnp.float32)
    got = [logits]
    for t in range(prompt, s):
        logits, caches = api.decode_step(params, cfg, tokens[:, t], caches)
        got.append(logits)
    got = jnp.stack(got, axis=1)  # (B, s-prompt+1, V)
    want = train_logits[:, prompt - 1:, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_llava_prefix_consistency():
    """The image prefix shifts the loss window correctly."""
    cfg = dataclasses.replace(configs.get_reduced("llava-next-mistral-7b"),
                              dtype="float32", remat=False)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch_for(cfg, 0, 2, 32)
    loss, metrics = lm.lm_loss(params, cfg, batch)
    assert jnp.isfinite(loss)
    assert int(metrics["tokens"]) == 2 * batch["labels"].shape[1]


def test_moe_capacity_dispatch_properties():
    from repro.models import moe
    cfg = configs.get_reduced("deepseek-moe-16b")
    key = jax.random.PRNGKey(1)
    g, t = 2, 64
    gates = jax.nn.softmax(jax.random.normal(key, (g, t, cfg.top_k)))
    idx = jax.random.randint(jax.random.PRNGKey(2), (g, t, cfg.top_k), 0,
                             cfg.n_experts)
    disp, comb = moe._dispatch_combine(cfg, gates, idx, t)
    cap = moe._capacity(t, cfg)
    assert disp.shape == (g, t, cfg.n_experts, cap)
    # a (expert, slot) pair is used by at most one token
    per_slot = jnp.sum(disp, axis=1)
    assert float(jnp.max(per_slot)) <= 1.0 + 1e-6
    # each token occupies at most top_k slots, combine weights <= its gates
    per_token = jnp.sum(disp, axis=(2, 3))
    assert float(jnp.max(per_token)) <= cfg.top_k + 1e-6
    cw = jnp.sum(comb, axis=(2, 3))
    gw = jnp.sum(gates, axis=-1)
    assert bool(jnp.all(cw <= gw + 1e-5))


def test_scan_vs_unrolled_layers_identical():
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              dtype="float32", remat=False)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch_for(cfg, 0, 2, 32)
    l1, _ = lm.lm_loss(params, cfg, batch)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2, _ = lm.lm_loss(params, cfg2, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_chunked_ce_matches_full_softmax():
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              dtype="float32", loss_chunk=8)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 20  # s % chunk != 0: exercises padding
    batch = make_batch_for(cfg, 0, b, s)
    x = lm.embed_tokens(params, cfg, batch["tokens"])
    hidden, _, _ = lm.forward_hidden(params, cfg, x, mode="train")
    nll, count = lm.chunked_ce(params, cfg, hidden,
                               batch["labels"],
                               jnp.ones_like(batch["labels"], jnp.float32))
    logits = lm.logits_for(params, cfg, hidden)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["labels"][..., None],
                             axis=-1)[..., 0]
    want = jnp.sum(lse - ll)
    np.testing.assert_allclose(float(nll), float(want), rtol=1e-5)
    assert int(count) == b * s


def test_greedy_generate_runs():
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"))
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompt = jnp.ones((2, 8), jnp.int32)
    out = lm.greedy_generate(params, cfg, prompt, n_new=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))

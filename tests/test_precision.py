"""Mixed-precision rollout gate (HITConfig/ChannelConfig `precision`).

bfloat16 advances the flow state inside `advance_rl_interval` only: states
are cast to bf16 at the interval boundary, every RK substep carries bf16,
and the result is cast back to float32 before obs/reward/PPO see it.  These
tests pin the contract:

  * the field validates (unknown precision -> ValueError at first use);
  * a bf16 interval stays finite, returns float32, and lands within a
    pinned relative error of the fp32 interval;
  * the headline gate — a reduced-HIT PPO training curve in bf16 matches
    the fp32 curve within a pinned per-iteration tolerance (measured
    max deviation ~0.025 on return_norm; pinned at 4x headroom).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import channel as channel_mod
from repro.cfd import initial, solver
from repro.cfd.channel import ChannelConfig
from repro.cfd.solver import HITConfig
from repro.core.orchestrator import FleetConfig
from repro.core.runner import Runner, RunnerConfig
from repro.envs import registry

# Pinned tolerances.
ADVANCE_REL_L2 = 0.05       # one RL interval, bf16 vs fp32 (measured ~0.007)
CURVE_ATOL = 0.1            # per-iteration return_norm (measured ~0.025)


@pytest.mark.parametrize("cfg_cls", [HITConfig, ChannelConfig])
def test_precision_field_validates(cfg_cls):
    assert cfg_cls().compute_dtype == jnp.float32
    assert cfg_cls(precision="bf16").compute_dtype == jnp.bfloat16
    with pytest.raises(ValueError, match="precision"):
        _ = cfg_cls(precision="fp16").compute_dtype


def test_hit_bf16_advance_matches_fp32():
    cfg = HITConfig(n_poly=3, n_elem=2, use_kernels=False)
    cfg16 = dataclasses.replace(cfg, precision="bf16")
    u = initial.sample_initial_state(jax.random.PRNGKey(0), cfg)
    cs = jnp.full((cfg.n_elem,) * 3, 0.17, jnp.float32)
    a32 = solver.advance_rl_interval(u, cs, cfg)
    a16 = solver.advance_rl_interval(u, cs, cfg16)
    assert a16.dtype == jnp.float32      # f32 restored at the boundary
    assert bool(jnp.all(jnp.isfinite(a16)))
    rel = float(jnp.linalg.norm(a16 - a32) / jnp.linalg.norm(a32))
    assert rel < ADVANCE_REL_L2


def test_channel_bf16_advance_matches_fp32():
    cfg = ChannelConfig(n_elem=(2, 3, 2), use_kernels=False)
    cfg16 = dataclasses.replace(cfg, precision="bf16")
    u = channel_mod.sample_initial_state(jax.random.PRNGKey(1), cfg)
    kx, _, kz = cfg.n_elem
    scale = jnp.ones((kx, kz), jnp.float32)
    a32 = channel_mod.advance_rl_interval(u, scale, scale, cfg)
    a16 = channel_mod.advance_rl_interval(u, scale, scale, cfg16)
    assert a16.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(a16)))
    rel = float(jnp.linalg.norm(a16 - a32) / jnp.linalg.norm(a32))
    assert rel < ADVANCE_REL_L2


def _training_curve(precision, tmp_path):
    env = registry.make("hit_les_reduced", precision=precision)
    ckpt = tmp_path / f"ckpt_{precision}"
    runner = Runner(env, FleetConfig(n_envs=2, bank_size=4),
                    run_cfg=RunnerConfig(n_iterations=3,
                                         checkpoint_dir=str(ckpt),
                                         async_checkpoint=False, seed=0))
    history = runner.train(resume=False)
    return np.array([rec["return_norm"] for rec in history])


def test_bf16_training_curve_matches_fp32(tmp_path):
    """The acceptance gate for the opt-in bf16 rollout: same seeds, same
    fleet, only the state-advance precision differs — the PPO learning
    curves must agree within the pinned tolerance."""
    c_fp32 = _training_curve("fp32", tmp_path)
    c_bf16 = _training_curve("bf16", tmp_path)
    assert c_fp32.shape == c_bf16.shape == (3,)
    assert np.all(np.isfinite(c_bf16))
    np.testing.assert_allclose(c_bf16, c_fp32, atol=CURVE_ATOL)

"""Docs stay truthful: intra-repo links resolve and env doctests pass.

The CI `docs` job runs `pytest --doctest-modules src/repro/envs` plus this
module; the link checker also runs in tier-1 so a moved file or a renamed
doc breaks the build immediately, not when a reader hits the 404.
"""
import doctest
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted([REPO / "README.md", *(REPO / "docs").glob("*.md")])

# [text](target) — inline markdown links, excluding images
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def _strip_code_blocks(text: str) -> str:
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def test_link_regex_finds_known_links():
    """Canary for the checker itself: the README is known to carry
    intra-repo links, so an all-clear with zero matches means the regex
    broke, not that the docs went link-free."""
    assert _LINK.findall(_strip_code_blocks((REPO / "README.md").read_text()))


@pytest.mark.parametrize("md", DOC_FILES, ids=lambda p: str(p.relative_to(REPO)))
def test_intra_repo_links_resolve(md):
    """Every relative link in README.md / docs/*.md points at a real file."""
    text = _strip_code_blocks(md.read_text())
    targets = _LINK.findall(text)
    missing = []
    for target in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:  # pure in-page anchor
            continue
        if not (md.parent / path).exists():
            missing.append(target)
    assert not missing, f"{md.name}: broken intra-repo links: {missing}"


def _env_modules():
    from repro import envs
    from repro.envs import base, burgers, channel, hit_les, registry

    return [envs, base, registry, burgers, channel, hit_les]


@pytest.mark.parametrize("module", _env_modules(),
                         ids=lambda m: m.__name__)
def test_env_module_doctests(module):
    """The `>>>` examples in the env modules execute as written (the same
    set `pytest --doctest-modules src/repro/envs` sweeps in the docs job)."""
    results = doctest.testmod(module, verbose=False)
    assert results.failed == 0, f"{module.__name__}: {results.failed} doctest failures"


def test_env_modules_carry_doctests():
    """At least the spec and registry modules document themselves with
    runnable examples — the docs job must have something to execute."""
    finder = doctest.DocTestFinder()
    total = sum(len(t.examples)
                for m in _env_modules() for t in finder.find(m))
    assert total >= 2

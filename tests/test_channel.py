"""Wall-BC DGSEM tests: the channel substrate's boundary abstraction.

Pins the two contracts the BC refactor promises: (i) with walls disabled
the mixed-BC assembly is BIT-IDENTICAL to the periodic HIT path, and
(ii) with walls enabled the weak wall fluxes conserve mass exactly while
exchanging momentum/energy only through the modeled wall stress."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd import channel, dgsem, equations, initial, solver
from repro.cfd.channel import ChannelConfig
from repro.cfd.dgsem import DGParams
from repro.cfd.solver import HITConfig

CFG = ChannelConfig(n_elem=(2, 3, 2), t_end=0.3)


def _weights_dg(cfg: ChannelConfig) -> DGParams:
    """DGParams stand-in for quadrature weights (element count is read off
    the array by dgsem.quadrature_mean, so any K works)."""
    return DGParams(cfg.n_poly, 1)


def _neutral_scales(cfg: ChannelConfig, value: float = 1.0):
    kx, _, kz = cfg.n_elem
    s = jnp.full((kx, kz), value, jnp.float32)
    return s, s


# --- BC abstraction ---------------------------------------------------------
def test_left_faces_periodic_is_roll():
    x = jnp.arange(2 * 3 * 2 * 4 * 4 * 5, dtype=jnp.float32).reshape(
        (2, 3, 2, 4, 4, 5))  # y-face array: node axis of d=1 removed
    np.testing.assert_array_equal(
        np.asarray(dgsem.left_faces(x, 1)),
        np.asarray(jnp.roll(x, 1, axis=1)))


def test_left_faces_wall_overrides_element_zero():
    x = jnp.ones((2, 3, 2, 4, 4, 5), jnp.float32)
    bc = jnp.full((2, 2, 4, 4, 5), 7.0, jnp.float32)
    out = dgsem.left_faces(x, 1, lo_value=bc)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(bc))
    np.testing.assert_array_equal(np.asarray(out[:, 1:]),
                                  np.ones((2, 2, 2, 4, 4, 5), np.float32))


def test_set_face_hi():
    x = jnp.zeros((2, 3, 2, 4, 4, 5), jnp.float32)
    bc = jnp.full((2, 2, 4, 4, 5), 3.0, jnp.float32)
    out = dgsem.set_face(x, 1, -1, bc)
    np.testing.assert_array_equal(np.asarray(out[:, -1]), np.asarray(bc))
    assert float(jnp.sum(jnp.abs(out[:, :-1]))) == 0.0


# --- reduction to the periodic path ----------------------------------------
def test_wall_off_reduces_to_periodic_hit_rhs():
    """cfg.wall=False on a cubic box must reproduce the periodic HIT RHS
    bit-for-bit (same helpers, same assembly order)."""
    length = 2.0 * np.pi
    hit = HITConfig(n_poly=3, n_elem=2, forcing_a0=0.0, nu=5e-3)
    ch = ChannelConfig(n_poly=3, n_elem=(2, 2, 2),
                       lengths=(length, length, length), nu=5e-3,
                       mach=hit.mach, u_bulk=hit.u_rms, wall=False,
                       u_tau=0.0, cs_sgs=0.17)
    u = initial.sample_initial_state(jax.random.PRNGKey(0), hit)
    cs_nodes = jnp.full(u.shape[:-1], 0.17, u.dtype)
    r_hit = solver.navier_stokes_rhs(u, cs_nodes, hit, hit.operators())
    scales = _neutral_scales(ch)
    r_ch = channel.channel_rhs(u, *scales, ch, ch.operators())
    np.testing.assert_array_equal(np.asarray(r_hit), np.asarray(r_ch))


# --- conservation with walls on --------------------------------------------
def test_wall_bc_conserves_mass():
    """The wall mass flux is exactly zero and the interior split form is
    conservative: total mass must survive many RL intervals to round-off."""
    u0 = channel.sample_initial_state(jax.random.PRNGKey(1), CFG)
    u = u0
    for _ in range(3):
        u = channel.advance_rl_interval(u, *_neutral_scales(CFG), CFG)
    assert bool(jnp.all(jnp.isfinite(u)))
    m0 = dgsem.quadrature_mean(u0, _weights_dg(CFG))
    m1 = dgsem.quadrature_mean(u, _weights_dg(CFG))
    np.testing.assert_allclose(float(m1[0]), float(m0[0]), rtol=1e-6)


def test_wall_stress_decelerates_unforced_flow():
    """Without forcing the only x-momentum sink is the modeled wall stress:
    bulk momentum must decrease, and faster with a larger stress scaling."""
    cfg = dataclasses.replace(CFG, u_tau=0.0)  # f_x = 0, walls still on
    u0 = channel.sample_initial_state(jax.random.PRNGKey(2), cfg)
    mom0 = float(dgsem.quadrature_mean(u0, _weights_dg(cfg))[1])
    assert mom0 > 0.0
    moms = {}
    for a in (0.5, 2.0):
        u = channel.advance_rl_interval(u0, *_neutral_scales(cfg, a), cfg)
        moms[a] = float(dgsem.quadrature_mean(u, _weights_dg(cfg))[1])
    assert moms[0.5] < mom0
    assert moms[2.0] < moms[0.5]


def test_wall_model_laminar_limit():
    """In the viscous sublayer (tiny y+) the inverted wall law must reduce
    to the laminar stress mu * u_par / y_m."""
    cfg = CFG
    u_par = jnp.asarray(0.01, jnp.float32)
    y_m = 1e-3
    tau = channel.wall_stress_magnitude(u_par, jnp.asarray(cfg.rho0), y_m, cfg)
    np.testing.assert_allclose(float(tau),
                               cfg.rho0 * cfg.nu * float(u_par) / y_m,
                               rtol=1e-2)


def test_reference_profile_symmetric_and_positive():
    ref = channel.reference_profile(CFG)
    assert ref.shape == (CFG.n_elem[1], CFG.n)
    flat = ref.reshape(-1)
    np.testing.assert_allclose(flat, flat[::-1], atol=1e-6)
    assert (ref >= 0.0).all()
    assert float(ref.max()) > CFG.u_tau  # outer flow well above u_tau


def test_profile_error_batch_shapes():
    """Profile + reward reduce correctly over a leading env batch."""
    ops = CFG.operators()
    bank = channel.make_state_bank(jax.random.PRNGKey(3), CFG, 2)
    prof = channel.mean_velocity_profile(bank, CFG, ops)
    assert prof.shape == (2, CFG.n_elem[1], CFG.n)
    ref = jnp.asarray(channel.reference_profile(CFG))
    ell = channel.profile_error(prof, ref, ops)
    assert ell.shape == (2,)
    assert bool(jnp.all(jnp.isfinite(ell)))

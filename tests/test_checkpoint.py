"""Fault tolerance: atomic checkpoints, corruption detection, crash-replay
recovery, elastic mesh-shape changes."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import relexi_hit
from repro.core import checkpoints
from repro.core.orchestrator import FleetConfig
from repro.core.runner import Runner, RunnerConfig


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    checkpoints.save(d, 3, tree, meta={"note": "x"})
    got, manifest = checkpoints.restore(d, 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["meta"]["note"] == "x"
    assert checkpoints.latest_step(d) == 3


def test_corruption_detected(tmp_path):
    d = str(tmp_path / "ck")
    checkpoints.save(d, 1, _tree())
    path = os.path.join(d, "step_00000001", "0.npy")
    raw = bytearray(open(path, "rb").read())
    raw[-1] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    with pytest.raises(checkpoints.IntegrityError):
        checkpoints.restore(d, 1, _tree())


def test_incomplete_checkpoint_skipped(tmp_path):
    d = str(tmp_path / "ck")
    checkpoints.save(d, 1, _tree())
    # simulate a crash mid-write: step dir without manifest
    os.makedirs(os.path.join(d, "step_00000005"))
    assert checkpoints.latest_step(d) == 1


def test_pruning_keeps_newest(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(5):
        checkpoints.save(d, s, _tree(), keep=2)
    assert checkpoints.all_steps(d) == [3, 4]


def test_restore_with_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    tree = _tree()
    checkpoints.save(d, 0, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    got, _ = checkpoints.restore(d, 0, tree, shardings=sh)
    assert got["a"].sharding == NamedSharding(mesh, P())


def test_runner_recovers_from_injected_failure(tmp_path):
    """Paper-scale fleets lose nodes; the runner must replay the iteration
    deterministically from consistent state."""
    env_cfg = relexi_hit.reduced()
    boom = {"done": False}

    def injector(k):
        if k == 1 and not boom["done"]:
            boom["done"] = True
            raise RuntimeError("injected node failure")

    r = Runner(env_cfg, FleetConfig(n_envs=2, bank_size=3),
               run_cfg=RunnerConfig(n_iterations=2, eval_every=100,
                                    checkpoint_every=1,
                                    checkpoint_dir=str(tmp_path / "rl"),
                                    async_checkpoint=False),
               failure_injector=injector)
    history = r.train()
    assert len(history) == 2
    assert boom["done"]
    # metrics file records the retry
    lines = [json.loads(l) for l in open(r.metrics_path)]
    assert any("retry" in rec for rec in lines)


def test_runner_resume_deterministic(tmp_path):
    """Same seed + checkpoint resume == uninterrupted run (bitwise params)."""
    env_cfg = relexi_hit.reduced()
    ck1 = str(tmp_path / "a")
    r1 = Runner(env_cfg, FleetConfig(n_envs=2, bank_size=3),
                run_cfg=RunnerConfig(n_iterations=2, eval_every=100,
                                     checkpoint_every=1, checkpoint_dir=ck1,
                                     async_checkpoint=False))
    r1.train()
    # interrupted run: 1 iteration, then a fresh Runner resumes to 2
    ck2 = str(tmp_path / "b")
    r2a = Runner(env_cfg, FleetConfig(n_envs=2, bank_size=3),
                 run_cfg=RunnerConfig(n_iterations=1, eval_every=100,
                                      checkpoint_every=1, checkpoint_dir=ck2,
                                      async_checkpoint=False))
    r2a.train()
    r2b = Runner(env_cfg, FleetConfig(n_envs=2, bank_size=3),
                 run_cfg=RunnerConfig(n_iterations=2, eval_every=100,
                                      checkpoint_every=1, checkpoint_dir=ck2,
                                      async_checkpoint=False))
    r2b.train()
    for a, b in zip(jax.tree.leaves(r1.params), jax.tree.leaves(r2b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_elastic_fleet_resize():
    from repro.core import elastic
    mesh = jax.make_mesh((1,), ("data",))
    assert elastic.elastic_fleet(16, mesh) == 16
    assert elastic.elastic_fleet(16, None) == 16


def test_lm_train_checkpoint_resume(tmp_path):
    """launch/train.py-style resume: params + stream cursor restored."""
    from repro import configs, optim
    from repro.data import TokenStream
    from repro.models import api
    cfg = configs.get_reduced("h2o-danube-1.8b")
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adam_init(params)
    stream = TokenStream(cfg, 2, 16, seed=7)
    step = jax.jit(lambda p, o, b: api.train_step(p, o, b, cfg))
    params, opt, _ = step(params, opt, stream.next())
    d = str(tmp_path / "lm")
    checkpoints.save(d, 1, {"params": jax.device_get(params),
                            "opt": jax.device_get(opt)},
                     meta={"stream": stream.state_dict()})
    tree, manifest = checkpoints.restore(d, 1, {"params": params, "opt": opt})
    s2 = TokenStream(cfg, 2, 16)
    s2.load_state_dict(manifest["meta"]["stream"])
    assert s2.cursor == stream.cursor and s2.seed == 7
    b1, b2 = stream.next(), s2.next()
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))

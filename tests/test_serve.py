"""Serving subsystem conformance suite (repro.serve).

The acceptance-critical pins:

  * served actions are BIT-IDENTICAL to training-time multitask policy
    evaluation (`multitask.actor_mean` == the `deterministic=True`
    rollout path) for EVERY registered scenario at fp32;
  * the checkpoint -> serve round trip reproduces the in-memory trained
    policy exactly on a reduced fleet run;
  * the batcher's host-side contracts: arbitrary submit interleavings
    preserve per-request ordering, padding rows never leak to a caller,
    bucket selection is a deterministic pure function, batch-of-1 equals
    batch-of-N row-wise, and slot recycling stays bounded/deterministic
    (hypothesis properties where the input space is combinatorial);
  * a checkpoint written on a DIFFERENT mesh shape restores and serves
    bit-identically (`core/elastic.reshard` re-placement).
"""
import json
import os
import socket
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import envs, fleet, serve
from repro.core import checkpoints
from repro.fleet import multitask
from repro.fleet.pipeline import FleetRunnerConfig
from repro.serve import (DEFAULT_BUCKETS, ControllerService, RequestBatcher,
                         bucket_for)

SCENARIOS = ("hit_les_reduced", "burgers_reduced")


def _mcfg(names=SCENARIOS) -> multitask.MultiTaskConfig:
    return multitask.MultiTaskConfig.from_envs(
        [(n, envs.make(n)) for n in names])


def _rand_obs(mcfg, name: str, n: int, seed: int = 1) -> np.ndarray:
    head = mcfg.head(name)
    shape = (n, head.n_elements, *head.spatial, head.channels)
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed), shape,
                                        "float32"))


def _service(names=SCENARIOS, **kwargs) -> tuple[ControllerService, dict]:
    mcfg = _mcfg(names)
    params = multitask.init(jax.random.PRNGKey(0), mcfg)
    return ControllerService(params, mcfg, **kwargs), params


def _trained_checkpoint(tmpdir, n_iterations: int = 2):
    """A short reduced fleet run that leaves a checkpoint; returns the
    runner (its in-memory params are the serving reference)."""
    runner = fleet.make_fleet_runner(
        SCENARIOS, total_envs=4,
        run_cfg=FleetRunnerConfig(
            n_iterations=n_iterations, eval_every=100,
            checkpoint_every=n_iterations, async_checkpoint=False,
            checkpoint_dir=str(tmpdir), bank_size=4),
        use_artifacts=False)
    runner.train(resume=False)
    assert checkpoints.latest_step(str(tmpdir)) is not None
    return runner


# --- bucket selection ---------------------------------------------------------
def test_bucket_for_minimal_and_deterministic():
    for n in range(1, DEFAULT_BUCKETS[-1] + 1):
        b = bucket_for(n)
        assert b >= n
        # minimality: no smaller ladder bucket fits
        assert all(s < n for s in DEFAULT_BUCKETS if s < b)
        assert bucket_for(n) == b  # pure
    assert bucket_for(3, (2, 5, 9)) == 5


def test_bucket_for_rejects_out_of_range():
    with pytest.raises(ValueError):
        bucket_for(0)
    with pytest.raises(ValueError):
        bucket_for(-2)
    with pytest.raises(ValueError):
        bucket_for(DEFAULT_BUCKETS[-1] + 1)


# --- batcher (deterministic pins) ---------------------------------------------
def _row(v: float, shape=(2, 3)) -> np.ndarray:
    return np.full(shape, v, np.float32)


def test_batcher_fifo_order_and_chunking():
    b = RequestBatcher(("a", "b"), buckets=(1, 2, 4), max_slots=32)
    uids = [b.submit("a", _row(i)) for i in range(6)]  # 6 > cap 4: chunks
    uid_b = b.submit("b", _row(99.0))
    batches = b.flush()
    # declared scenario order; 'a' chunked into a full max bucket + remainder
    assert [x.scenario for x in batches] == ["a", "a", "b"]
    assert batches[0].uids == tuple(uids[:4]) and batches[0].n_valid == 4
    assert batches[1].uids == tuple(uids[4:]) and batches[1].n_valid == 2
    assert batches[1].bucket == 2
    assert batches[2].uids == (uid_b,) and batches[2].bucket == 1
    for batch in batches:  # rows are the submitted obs, in arrival order
        for i, uid in enumerate(batch.uids):
            np.testing.assert_array_equal(batch.obs[i], _row(float(uid))
                                          if batch.scenario == "a"
                                          else _row(99.0))
    assert b.n_pending == 0 and b.flush() == []


def test_batcher_padding_repeats_last_real_row():
    b = RequestBatcher(("a",), buckets=(4,), max_slots=8)
    for i in range(3):
        b.submit("a", _row(float(i)))
    (batch,) = b.flush()
    assert batch.bucket == 4 and batch.n_valid == 3
    np.testing.assert_array_equal(batch.obs[3], batch.obs[2])  # the pad row
    assert len(batch.uids) == len(batch.slots) == 3  # pads carry no identity


def test_batcher_slot_recycling_lowest_first():
    b = RequestBatcher(("a",), buckets=(1, 2, 4), max_slots=4)
    b.submit("a", _row(0))
    b.submit("a", _row(1))
    (batch,) = b.flush()
    assert batch.slots == (0, 1)
    b.release(0)          # slot 1 still outstanding
    assert b.n_free_slots == 3
    b.submit("a", _row(2))
    (batch2,) = b.flush()
    assert batch2.slots == (0,)  # lowest free slot reused deterministically
    with pytest.raises(ValueError):
        b.release(2)       # never handed out
    with pytest.raises(ValueError):
        b.release(99)      # out of range


def test_batcher_backpressure_and_unknown_scenario():
    b = RequestBatcher(("a",), buckets=(1, 2), max_slots=2)
    b.submit("a", _row(0))
    b.submit("a", _row(1))
    with pytest.raises(RuntimeError, match="no free request slots"):
        b.submit("a", _row(2))
    with pytest.raises(KeyError, match="unknown scenario"):
        b.submit("nope", _row(0))
    (batch,) = b.flush()
    for s in batch.slots:
        b.release(s)
    assert b.submit("a", _row(3)) == 2  # uids keep counting after recovery


def test_batcher_rejects_bad_buckets():
    for bad in ((), (2, 1), (1, 1, 2), (0, 1)):
        with pytest.raises((ValueError, IndexError)):
            RequestBatcher(("a",), buckets=bad)


# --- batcher (hypothesis properties) ------------------------------------------
def test_batcher_interleaving_properties():
    """Arbitrary submit interleavings across scenarios: per-scenario FIFO
    uid order survives batching, every request appears exactly once, rows
    match their uids, and bucket selection is the pure minimal bucket."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=150, deadline=None)
    @given(plan=st.lists(st.sampled_from(["a", "b", "c"]),
                         min_size=1, max_size=40))
    def prop(plan):
        b = RequestBatcher(("a", "b", "c"), buckets=(1, 2, 4, 8),
                           max_slots=64)
        submitted = {"a": [], "b": [], "c": []}
        for scen in plan:
            uid = b.submit(scen, _row(0.0))
            submitted[scen].append(uid)
        batches = b.flush()
        seen = {"a": [], "b": [], "c": []}
        for batch in batches:
            assert batch.bucket == bucket_for(batch.n_valid, (1, 2, 4, 8))
            assert len(batch.uids) == batch.n_valid <= batch.bucket
            assert batch.obs.shape[0] == batch.bucket  # padded to the bucket
            seen[batch.scenario].extend(batch.uids)
        for scen in ("a", "b", "c"):  # FIFO per scenario, nothing lost/dup'd
            assert seen[scen] == submitted[scen]
        assert b.n_free_slots == 64 - len(plan)  # pads consumed no slots

    prop()


def test_batcher_slot_pool_bounded_property():
    """Any submit/flush+release schedule keeps outstanding slots <=
    max_slots, refuses loudly at the bound, and recycles released ids."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=100, deadline=None)
    @given(ops=st.lists(st.sampled_from(["submit", "drain"]),
                        min_size=1, max_size=30))
    def prop(ops):
        cap = 4
        b = RequestBatcher(("a",), buckets=(1, 2, 4), max_slots=cap)
        outstanding = 0
        for op in ops:
            if op == "submit":
                if outstanding == cap:
                    with pytest.raises(RuntimeError):
                        b.submit("a", _row(0.0))
                else:
                    b.submit("a", _row(0.0))
                    outstanding += 1
            else:
                for batch in b.flush():
                    for s in batch.slots:
                        b.release(s)
                        outstanding -= 1
            assert b.n_free_slots == cap - outstanding
        all_slots = [s for batch in b.flush() for s in batch.slots]
        assert all(0 <= s < cap for s in all_slots)

    prop()


def test_serve_batch1_equals_batchN_property():
    """Row-wise bit-identity between batch-of-1 and batch-of-N serving —
    padding and batch position must not perturb a row's action."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    svc, params = _service(("burgers_reduced",), buckets=(1, 2, 4, 8),
                           max_slots=32)
    obs = _rand_obs(svc.mcfg, "burgers_reduced", 8)
    singles = np.stack([svc.serve_batch("burgers_reduced", obs[i:i + 1])[0]
                        for i in range(8)])

    @settings(max_examples=25, deadline=None)
    @given(rows=st.lists(st.integers(min_value=0, max_value=7),
                         min_size=1, max_size=8))
    def prop(rows):
        got = svc.serve_batch("burgers_reduced", obs[rows])
        np.testing.assert_array_equal(got, singles[rows])

    prop()


# --- service conformance ------------------------------------------------------
def test_served_actions_bit_identical_all_registered_scenarios():
    """THE conformance pin: for every scenario in the registry, the served
    greedy action equals training-time multitask evaluation bit-for-bit at
    fp32 — through the full submit/pad/dispatch/slice path, at a batch
    size that forces padding."""
    names = envs.registered()
    mcfg = _mcfg(names)
    params = multitask.init(jax.random.PRNGKey(7), mcfg)
    svc = ControllerService(params, mcfg, buckets=(1, 2, 4), max_slots=16)
    ref = jax.jit(multitask.actor_mean, static_argnums=(1, 2))
    for name in names:
        obs = _rand_obs(mcfg, name, 3, seed=11)  # 3 -> bucket 4: one pad row
        got = svc.serve_batch(name, obs)
        want = np.asarray(ref(params, mcfg, name, obs))
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, want), name


def test_served_actions_match_training_policy_fns():
    """The training rollout's deterministic path goes through
    `multitask.policy_fns(...).mean` — pin the service against that exact
    adapter, not just actor_mean."""
    svc, params = _service()
    for name in SCENARIOS:
        fns = multitask.policy_fns(svc.mcfg, name)
        obs = _rand_obs(svc.mcfg, name, 2, seed=3)
        got = svc.serve_batch(name, obs)
        want = np.asarray(jax.jit(fns.mean)(params, obs))
        np.testing.assert_array_equal(got, want)


def test_flush_results_and_telemetry():
    svc, _ = _service(buckets=(1, 2, 4), max_slots=16)
    uids = {}
    for name in SCENARIOS:
        for i in range(3):
            uids[svc.submit(name, _rand_obs(svc.mcfg, name, 1, seed=i)[0])] \
                = name
    results = svc.flush()
    assert set(results) == set(uids)  # every request answered, none extra
    for uid, res in results.items():
        assert res.uid == uid and res.scenario == uids[uid]
        head = svc.mcfg.head(res.scenario)
        assert res.action.shape == (head.n_elements,)
        assert np.isfinite(res.action).all() and np.isfinite(res.value)
    stats = svc.stats()
    for name in SCENARIOS:  # 3 requests -> one padded bucket-4 batch each
        assert stats[name] == {"requests": 3, "batches": 1}
    assert svc.flush() == {}  # drained
    assert svc.batcher.n_free_slots == 16  # all slots recycled


def test_submit_shape_checked_at_the_edge():
    svc, _ = _service()
    good = _rand_obs(svc.mcfg, "burgers_reduced", 1)[0]
    with pytest.raises(ValueError, match="observation shape"):
        svc.submit("burgers_reduced", good[:-1])
    with pytest.raises(KeyError):
        svc.submit("not_registered", good)
    assert svc.batcher.n_pending == 0  # rejected requests consumed nothing


# --- checkpoint -> serve ------------------------------------------------------
def test_checkpoint_serve_bit_identical_to_trained_policy(tmp_path):
    """Reduced fleet run -> checkpoint -> `load_service`: the restored
    params ARE the trained params (leaf-wise exact) and the served actions
    equal in-memory training-time evaluation bit-for-bit."""
    runner = _trained_checkpoint(tmp_path / "ckpt")
    svc = serve.load_service(str(tmp_path / "ckpt"), max_slots=16)
    assert svc.scenarios == SCENARIOS

    trained = jax.tree.leaves(runner.params)
    restored = jax.tree.leaves(svc.params)
    assert len(trained) == len(restored)
    for a, b in zip(trained, restored):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    for name in SCENARIOS:
        obs = _rand_obs(svc.mcfg, name, 3, seed=5)
        got = svc.serve_batch(name, obs)
        want = np.asarray(multitask.actor_mean(runner.params, runner.mcfg,
                                               name, jnp.asarray(obs)))
        np.testing.assert_array_equal(got, want)


def test_load_policy_provenance_and_specific_step(tmp_path):
    _trained_checkpoint(tmp_path / "ckpt")
    step = checkpoints.latest_step(str(tmp_path / "ckpt"))
    policy = serve.load_policy(str(tmp_path / "ckpt"), step)
    assert policy.step == step
    assert policy.scenarios == SCENARIOS
    assert policy.meta["scenarios"] == list(SCENARIOS)
    assert policy.meta["d_embed"] == policy.mcfg.d_embed
    with pytest.raises(FileNotFoundError):
        serve.load_policy(str(tmp_path / "empty"))


# --- loader robustness --------------------------------------------------------
def _manifest_path(ckpt_dir: str) -> str:
    step = checkpoints.latest_step(ckpt_dir)
    return os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")


def test_loader_infers_trunk_from_legacy_manifest(tmp_path):
    """Checkpoints written before the explicit d_embed/n_shared_layers meta
    fields must stay loadable — the loader reads the trunk shape off the
    manifest key lattice."""
    _trained_checkpoint(tmp_path / "ckpt")
    path = _manifest_path(str(tmp_path / "ckpt"))
    with open(path) as f:
        manifest = json.load(f)
    declared = (manifest["meta"].pop("d_embed"),
                manifest["meta"].pop("n_shared_layers"))
    with open(path, "w") as f:
        json.dump(manifest, f)
    policy = serve.load_policy(str(tmp_path / "ckpt"))
    assert (policy.mcfg.d_embed, policy.mcfg.n_shared_layers) == declared


def test_loader_rejects_mismatched_trunk_meta(tmp_path):
    _trained_checkpoint(tmp_path / "ckpt")
    path = _manifest_path(str(tmp_path / "ckpt"))
    with open(path) as f:
        manifest = json.load(f)
    manifest["meta"]["d_embed"] = 9999
    with open(path, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(checkpoints.IntegrityError, match="d_embed"):
        serve.load_policy(str(tmp_path / "ckpt"))


def test_loader_rejects_non_fleet_checkpoint(tmp_path):
    # a tree with a params subtree but no multitask trunk
    checkpoints.save(str(tmp_path), 1,
                     {"params": {"w": np.zeros((2, 2), np.float32)}},
                     meta={"scenarios": list(SCENARIOS)})
    with pytest.raises(checkpoints.IntegrityError, match="actor"):
        serve.load_policy(str(tmp_path))
    # and one with no scenario provenance at all
    checkpoints.save(str(tmp_path), 2,
                     {"params": {"w": np.zeros((2, 2), np.float32)}})
    with pytest.raises(checkpoints.IntegrityError, match="scenarios"):
        serve.load_policy(str(tmp_path))


# --- different-mesh restore (elastic.reshard) ---------------------------------
def test_load_policy_onto_explicit_mesh(tmp_path):
    """In-process reshard path: the restored tree re-places replicated on
    the serving host mesh and serves identically to the unplaced load."""
    from repro.launch import mesh as mesh_lib

    runner = _trained_checkpoint(tmp_path / "ckpt")
    svc = serve.load_service(str(tmp_path / "ckpt"),
                             mesh=mesh_lib.make_host_mesh(), max_slots=8)
    name = SCENARIOS[0]
    obs = _rand_obs(svc.mcfg, name, 2, seed=9)
    got = svc.serve_batch(name, obs)
    want = np.asarray(multitask.actor_mean(runner.params, runner.mcfg, name,
                                           jnp.asarray(obs)))
    np.testing.assert_array_equal(got, want)


_MESH_WORKER = r"""
import os, sys, json
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=2")
import jax
import numpy as np
assert len(jax.devices()) == 2, jax.devices()

from repro import fleet
from repro.fleet import multitask
from repro.fleet.pipeline import FleetRunnerConfig
from repro.launch import mesh as mesh_lib

ckpt_dir, ref_path = sys.argv[1], sys.argv[2]
mesh = mesh_lib.make_host_mesh()          # 2-device training mesh
assert int(np.prod(list(mesh.shape.values()))) == 2
runner = fleet.make_fleet_runner(
    ("hit_les_reduced", "burgers_reduced"), total_envs=4, mesh=mesh,
    run_cfg=FleetRunnerConfig(n_iterations=2, eval_every=100,
                              checkpoint_every=2, async_checkpoint=False,
                              checkpoint_dir=ckpt_dir, bank_size=4),
    use_artifacts=False)
runner.train(resume=False)

ref = {}
for name in runner.mcfg.names:
    head = runner.mcfg.head(name)
    obs = jax.random.normal(
        jax.random.PRNGKey(13),
        (3, head.n_elements, *head.spatial, head.channels), "float32")
    acts = multitask.actor_mean(runner.params, runner.mcfg, name, obs)
    ref[name] = {"obs": np.asarray(obs).tolist(),
                 "actions": np.asarray(acts).tolist()}
with open(ref_path, "w") as f:
    json.dump(ref, f)
print("mesh worker ok")
"""


@pytest.mark.slow
def test_restore_from_different_mesh_shape(tmp_path):
    """A checkpoint trained on a 2-device mesh (forced host platform
    devices, fresh subprocess) restores on this 1-device process and
    serves actions bit-identical to the training process's own
    evaluation."""
    ckpt_dir = str(tmp_path / "ckpt2dev")
    ref_path = str(tmp_path / "ref.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_WORKER, ckpt_dir, ref_path],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "mesh worker ok" in proc.stdout

    assert len(jax.devices()) == 1  # genuinely a different serving topology
    svc = serve.load_service(ckpt_dir, max_slots=8)
    with open(ref_path) as f:
        ref = json.load(f)
    for name, rec in ref.items():
        obs = np.asarray(rec["obs"], np.float32)
        want = np.asarray(rec["actions"], np.float32)
        got = svc.serve_batch(name, obs)
        np.testing.assert_array_equal(got, want)


# --- static-analysis registration ---------------------------------------------
def test_serve_entrypoint_registered_and_audits_clean():
    """The serve program is a first-class repro-lint entry: it traces, its
    donation expectations hold in the lowered program, and no audit rule
    fires."""
    from repro.analysis import entrypoints, jaxpr_audit

    entry = entrypoints.get("serve_step")
    findings = jaxpr_audit.audit_entry(entry)
    active = [f for f in findings if not f.suppressed]
    assert active == [], [f.message for f in active]

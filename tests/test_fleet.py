"""Fleet subsystem tests: broker ring semantics, scheduler determinism,
multitask heads, pipelined training, and the refactor's bit-identity pins.

The two acceptance-critical properties:

  * a mixed fleet (hit_les + channel_wm + burgers reduced) trains
    end-to-end through `FleetRunner.train` and replays BIT-IDENTICALLY
    through a checkpoint restore (the multi-scenario state tree — params,
    optimizer, broker rings — covers the in-flight trajectory);
  * the PolicyFns plumbing threaded through core/ leaves every
    single-scenario entry point bit-identical (rollout and PPO update
    through the adapter == the direct module functions).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import envs, fleet
from repro.core import policy as policy_lib
from repro.core import ppo as ppo_lib
from repro.core import rollout as rollout_lib
from repro.fleet import broker, multitask, scheduler
from repro.fleet.pipeline import FleetRunner, FleetRunnerConfig

FLEET_NAMES = ("hit_les_reduced", "channel_wm_reduced", "burgers_reduced")


def _item(v: float) -> dict:
    return {"a": jnp.full((), v, jnp.float32),
            "b": jnp.full((2, 3), v, jnp.float32)}


def _runner(tmpdir, n_iterations=3, **cfg_kw) -> FleetRunner:
    kw = dict(n_iterations=n_iterations, eval_every=100, checkpoint_every=100,
              checkpoint_dir=str(tmpdir), async_checkpoint=False, bank_size=4)
    kw.update(cfg_kw)
    return fleet.make_fleet_runner(FLEET_NAMES, total_envs=6,
                                   run_cfg=FleetRunnerConfig(**kw),
                                   use_artifacts=False)


# --- broker ring buffers ------------------------------------------------------
def test_ring_wraparound():
    ring = broker.ring_init(_item(0.0), 3)
    assert broker.capacity(ring) == 3
    assert int(broker.size(ring)) == 0
    for v in range(1, 6):  # five pushes through a capacity-3 ring
        ring = broker.push(ring, _item(float(v)))
    assert int(ring.head) == 5
    assert int(broker.size(ring)) == 3
    # newest-first reads wrap correctly: 5, 4, 3 survive; 1, 2 evicted
    for age, want in ((0, 5.0), (1, 4.0), (2, 3.0)):
        got = broker.peek(ring, age)
        assert float(got["a"]) == want
        np.testing.assert_array_equal(np.asarray(got["b"]),
                                      np.full((2, 3), want, np.float32))


def test_ring_push_donated_matches_push():
    r1 = broker.ring_init(_item(0.0), 2)
    r2 = broker.ring_init(_item(0.0), 2)
    for v in (1.0, 2.0, 3.0):
        r1 = broker.push(r1, _item(v))
        r2 = broker.push_donated(r2, _item(v))
    assert int(r1.head) == int(r2.head)
    for a, b in zip(jax.tree.leaves(r1), jax.tree.leaves(r2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_broker_metrics_drain_ordered():
    b = broker.broker_init({}, metric_templates={"m": _item(0.0)["a"]},
                           metrics_capacity=4)
    for v in range(1, 7):
        b = broker.push_metrics(b, "m", jnp.float32(v))
    records = broker.drain_host(b)["m"]
    assert records == [3.0, 4.0, 5.0, 6.0]  # oldest-first, capacity-bounded


# --- scheduler ----------------------------------------------------------------
def _named_envs():
    return [(n, envs.make(n)) for n in FLEET_NAMES]


def test_schedule_cost_weighted_partition():
    costs = {"hit_les_reduced": 4.0, "channel_wm_reduced": 40.0,
             "burgers_reduced": 1.0}
    sched = scheduler.build_schedule(_named_envs(), 20, costs=costs,
                                     use_artifacts=False)
    by_name = {m.name: m for m in sched.members}
    assert sched.total_envs == 20
    # cheaper scenarios get more envs; everyone gets at least one
    assert (by_name["burgers_reduced"].n_envs
            > by_name["hit_les_reduced"].n_envs
            > by_name["channel_wm_reduced"].n_envs >= 1)
    assert abs(sum(m.weight for m in sched.members) - 1.0) < 1e-9
    # deterministic: same inputs, same partition
    again = scheduler.build_schedule(_named_envs(), 20, costs=costs,
                                     use_artifacts=False)
    assert [(m.name, m.n_envs) for m in again.members] == \
           [(m.name, m.n_envs) for m in sched.members]


def test_schedule_static_costs_from_configs():
    sched = scheduler.build_schedule(_named_envs(), 12, use_artifacts=False)
    by_name = {m.name: m for m in sched.members}
    assert sched.total_envs == 12
    # the 3-D channel step costs orders of magnitude more than 1-D Burgers
    assert by_name["channel_wm_reduced"].cost > by_name["burgers_reduced"].cost
    assert (by_name["burgers_reduced"].n_envs
            >= by_name["channel_wm_reduced"].n_envs)


def test_schedule_min_envs_guard():
    with pytest.raises(ValueError, match="total_envs"):
        scheduler.build_schedule(_named_envs(), 2, use_artifacts=False,
                                 costs={n: 1.0 for n in FLEET_NAMES})


def test_dryrun_cost_artifact_feeds_scheduler(tmp_path):
    """Measured fleet-cell costs reach the scheduler — matched by exact
    scenario, and only when EVERY member has one (measured XLA FLOPs and
    the static DOF proxy are different units; a partial set must not mix
    inside one partition)."""
    cell = {"status": "ok", "arch": "channel-wm",
            "variant": "channel_wm_reduced", "flops_per_env": 2.0e6}
    with open(tmp_path / "single_channel-wm_fleet_256.json", "w") as f:
        json.dump(cell, f)
    hit = {"status": "ok", "arch": "relexi-hit24", "flops_per_env": 1.0e6}
    with open(tmp_path / "single_relexi-hit24_fleet_256_elem16.json",
              "w") as f:
        json.dump(hit, f)

    assert scheduler.dryrun_step_cost(
        "channel_wm_reduced", artifact_dir=str(tmp_path)) == 2.0e6
    assert scheduler.dryrun_step_cost(
        "hit_les_24dof", artifact_dir=str(tmp_path)) == 1.0e6
    # a cell measured at another scale must not price this scenario
    assert scheduler.dryrun_step_cost(
        "channel_wm", artifact_dir=str(tmp_path)) is None
    assert scheduler.dryrun_step_cost(
        "burgers_reduced", artifact_dir=str(tmp_path)) is None

    # fully-measured fleet: the artifacts become the weights
    measured = [("channel_wm_reduced", envs.make("channel_wm_reduced")),
                ("hit_les_24dof", envs.make("hit_les_24dof"))]
    sched = scheduler.build_schedule(measured, 9,
                                     artifact_dir=str(tmp_path))
    assert sched.member("channel_wm_reduced").cost == 2.0e6
    assert sched.member("hit_les_24dof").cost == 1.0e6
    assert (sched.member("hit_les_24dof").n_envs
            > sched.member("channel_wm_reduced").n_envs)

    # partially-measured fleet (burgers has no cell): everyone falls back
    # to the static proxy rather than mixing units
    mixed = scheduler.build_schedule(_named_envs(), 9,
                                     artifact_dir=str(tmp_path))
    assert mixed.member("channel_wm_reduced").cost != 2.0e6


def test_scenario_keys_deterministic_and_distinct():
    seeds = [scheduler.scenario_seed(0, i) for i in range(4)]
    assert len(set(seeds)) == 4
    key = jax.random.PRNGKey(7)
    k_a = scheduler.rollout_key(key, 0, 3)
    k_b = scheduler.rollout_key(key, 1, 3)
    k_c = scheduler.rollout_key(key, 0, 4)
    assert not np.array_equal(np.asarray(k_a), np.asarray(k_b))
    assert not np.array_equal(np.asarray(k_a), np.asarray(k_c))
    # pure function of (seed, scenario, iteration): replay regenerates it
    np.testing.assert_array_equal(
        np.asarray(k_a), np.asarray(scheduler.rollout_key(key, 0, 3)))


# --- multitask heads ----------------------------------------------------------
def test_multitask_heads_respect_specs():
    named = _named_envs()
    mcfg = multitask.MultiTaskConfig.from_envs(named)
    params = multitask.init(jax.random.PRNGKey(0), mcfg)
    for name, env in named:
        bank = env.initial_state_bank(jax.random.PRNGKey(1), 2)
        obs = jnp.stack([env.reset_from_bank(bank, jnp.asarray(i))[1]
                         for i in range(2)])
        mean = multitask.actor_mean(params, mcfg, name, obs)
        assert mean.shape == (2,) + env.action_spec.shape
        assert bool(jnp.all(mean >= env.action_spec.low))
        assert bool(jnp.all(mean <= env.action_spec.high))
        assert multitask.value(params, mcfg, name, obs).shape == (2,)


def test_multitask_policy_drives_unchanged_rollout():
    """A scenario head plugs into core rollout via the PolicyFns bundle."""
    named = _named_envs()
    mcfg = multitask.MultiTaskConfig.from_envs(named)
    params = multitask.init(jax.random.PRNGKey(2), mcfg)
    name, env = named[2]  # burgers: cheapest
    u0 = env.initial_state_bank(jax.random.PRNGKey(3), 2)
    traj = jax.jit(lambda p, u, k: rollout_lib.rollout(
        p, None, env, u, k, policy=multitask.policy_fns(mcfg, name))
    )(params, u0, jax.random.PRNGKey(4))
    assert traj.obs.shape[:2] == (env.n_actions, 2)
    assert bool(jnp.all(jnp.isfinite(traj.rewards)))


def test_shared_trunk_is_actually_shared():
    """Gradients from one scenario's loss touch the shared trunk params."""
    named = _named_envs()
    mcfg = multitask.MultiTaskConfig.from_envs(named)
    params = multitask.init(jax.random.PRNGKey(5), mcfg)
    name, env = named[2]
    bank = env.initial_state_bank(jax.random.PRNGKey(6), 2)
    obs = env.reset_from_bank(bank, jnp.asarray(0))[1][None]

    grads = jax.grad(
        lambda p: jnp.sum(multitask.actor_mean(p, mcfg, name, obs)))(params)
    assert any(float(jnp.max(jnp.abs(g))) > 0.0
               for g in jax.tree.leaves(grads["shared"]["actor"]))


# --- refactor pin: default policy path is bit-identical -----------------------
def test_policy_fns_adapter_bit_identical():
    """The PolicyFns indirection added for the fleet must not perturb the
    single-scenario path: rollout and PPO update through an explicit
    default-policy bundle match the policy=None path bit-for-bit (which
    itself is pinned against the pre-refactor formulas by test_envs)."""
    env = envs.make("burgers_reduced")
    pcfg = policy_lib.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
    params = policy_lib.init(jax.random.PRNGKey(0), pcfg)
    u0 = env.initial_state_bank(jax.random.PRNGKey(1), 2)
    key = jax.random.PRNGKey(2)

    roll = lambda policy: jax.jit(
        lambda p, u, k: rollout_lib.rollout(p, pcfg, env, u, k,
                                            policy=policy))(params, u0, key)
    t_default, t_adapter = roll(None), roll(policy_lib.policy_fns(pcfg))
    for got, want in zip(t_adapter, t_default):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    from repro import optim
    opt = optim.adam_init(params)
    cfg = ppo_lib.PPOConfig(n_epochs=2)
    upd = lambda policy: jax.jit(
        lambda p, o, t: ppo_lib.update(p, o, cfg, pcfg, t, policy=policy)
    )(params, opt, t_default)
    p_default, _, s_default = upd(None)
    p_adapter, _, s_adapter = upd(policy_lib.policy_fns(pcfg))
    for got, want in zip(jax.tree.leaves((p_adapter, s_adapter)),
                         jax.tree.leaves((p_default, s_default))):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- end-to-end fleet training ------------------------------------------------
def test_mixed_fleet_trains_and_logs(tmp_path):
    runner = _runner(tmp_path / "fleet", n_iterations=3, eval_every=2)
    history = runner.train(resume=False)
    assert len(history) == 3
    for rec in history:
        assert rec["update_ok"] == 1.0
        for name in FLEET_NAMES:
            assert np.isfinite(rec[f"{name}/return_norm"])
            assert -1.0 <= rec[f"{name}/return_norm"] <= 1.0
    # the eval cadence fired and logged per-scenario held-out returns
    with open(runner.metrics_path) as f:
        logged = [json.loads(line) for line in f]
    assert any(f"{FLEET_NAMES[0]}/eval_return_norm" in r for r in logged)


def test_mixed_fleet_bit_replay_after_restore(tmp_path):
    """Same seed => same params, straight through a checkpoint restore of
    the multi-scenario state tree (params + optimizer + broker rings)."""
    def make(d):
        return _runner(d, n_iterations=3, checkpoint_every=2)

    a = make(tmp_path / "a")
    a.train(resume=False)
    b = make(tmp_path / "b")
    b.train(2, resume=False)     # stop mid-run at the checkpoint
    b2 = make(tmp_path / "b")    # fresh process-state, same directory
    assert b2.restore()
    assert b2.iteration == 2
    b2.train(3, resume=False)    # already restored; continue to the end
    for got, want in zip(jax.tree.leaves(b2.params), jax.tree.leaves(a.params)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sync_mode_trains_with_timings(tmp_path):
    runner = _runner(tmp_path / "sync", n_iterations=2, pipelined=False)
    history = runner.train(resume=False)
    assert len(history) == 2
    for rec in history:
        assert rec["t_sample_s"] > 0.0 and rec["t_update_s"] > 0.0
        assert rec["update_ok"] == 1.0


def test_update_nonfinite_guard_keeps_params(tmp_path):
    """A poisoned trajectory must not advance params (in-graph guard —
    the pipelined loop never syncs to check on the host)."""
    runner = _runner(tmp_path / "guard", n_iterations=1)
    trajs = runner.forch.sample_all(runner.params, runner._keys(0))
    name = FLEET_NAMES[0]
    trajs[name] = trajs[name]._replace(
        rewards=trajs[name].rewards.at[0, 0].set(jnp.nan))
    new_params, _, stats = runner._update(
        runner.params, runner.opt_state, trajs, jnp.asarray(0, jnp.int32))
    assert float(stats["update_ok"]) == 0.0
    for got, want in zip(jax.tree.leaves(new_params),
                         jax.tree.leaves(runner.params)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- PR-8 bugfix regressions ---------------------------------------------------
def test_scenario_seed_no_additive_collision():
    """The former additive stride `base_seed + 7919*(index+1)` made
    `(s, i+1)` and `(s+7919, i)` share a bank seed; the fold_in derivation
    must keep them distinct (and stay a pure, stable function)."""
    assert (scheduler.scenario_seed(0, 1)
            != scheduler.scenario_seed(7919, 0))
    assert (scheduler.scenario_seed(3, 2)
            != scheduler.scenario_seed(3 + 7919, 1))
    # pure + stable within a run lineage
    assert scheduler.scenario_seed(5, 2) == scheduler.scenario_seed(5, 2)
    seeds = {scheduler.scenario_seed(s, i)
             for s in range(4) for i in range(4)}
    assert len(seeds) == 16


def test_draw_initial_states_rejects_zero_envs(tmp_path):
    """`n_envs=0` used to fall through `n_envs or fleet.n_envs` and
    silently sample the FULL fleet."""
    runner = _runner(tmp_path / "zero_envs", n_iterations=1)
    orch = runner.forch.orchs[FLEET_NAMES[0]]
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="positive"):
        orch.draw_initial_states(key, n_envs=0)
    with pytest.raises(ValueError, match="positive"):
        orch.draw_initial_states(key, n_envs=-2)
    # None still means the configured fleet size; explicit counts hold
    assert orch.draw_initial_states(key).shape[0] == orch.fleet.n_envs
    assert orch.draw_initial_states(key, n_envs=2).shape[0] == 2


def test_dryrun_cost_zero_measurement_fails_loudly(tmp_path):
    """A record carrying a measured `flops_per_env=0.0` used to be
    silently discarded by a truthiness check; it must raise (a zero cost
    would hand the scenario an infinite env share).  A record WITHOUT the
    field keeps scanning to older artifacts."""
    broken = {"status": "ok", "variant": "burgers_reduced",
              "flops_per_env": 0.0}
    with open(tmp_path / "a_fleet_1.json", "w") as f:
        json.dump(broken, f)
    with pytest.raises(ValueError, match="non-positive"):
        scheduler.dryrun_step_cost("burgers_reduced",
                                   artifact_dir=str(tmp_path))
    # a record with NO `arch` field must not match a scenario through the
    # legacy-tag fallback (None == None used to price any unlisted
    # scenario off an unrelated cell)
    assert scheduler.dryrun_step_cost("hit_les_24dof",
                                      artifact_dir=str(tmp_path)) is None

    import os
    import time as time_mod
    old = {"status": "ok", "variant": "channel_wm_reduced",
           "flops_per_env": 5.0e5}
    with open(tmp_path / "old_fleet_1.json", "w") as f:
        json.dump(old, f)
    missing = {"status": "ok", "variant": "channel_wm_reduced"}
    with open(tmp_path / "new_fleet_1.json", "w") as f:
        json.dump(missing, f)
    now = time_mod.time()
    os.utime(tmp_path / "old_fleet_1.json", (now - 100, now - 100))
    os.utime(tmp_path / "new_fleet_1.json", (now, now))
    # the newest record has no measurement -> fall back to the older one
    assert scheduler.dryrun_step_cost(
        "channel_wm_reduced", artifact_dir=str(tmp_path)) == 5.0e5


def test_broker_drains_vector_metrics_json_ready(tmp_path):
    """A vector-valued metric leaf used to come back from `drain_host` as
    a numpy array and crash the runner's `float(v)` record conversion."""
    from repro.fleet.pipeline import _host_record

    template = {"loss": jnp.zeros(()),
                "per_scenario_return": jnp.zeros((3,))}
    b = broker.broker_init({}, metric_templates={"fleet": template},
                           metrics_capacity=4)
    item = {"loss": jnp.asarray(0.5),
            "per_scenario_return": jnp.asarray([1.0, 2.0, 3.0])}
    b = broker.push_metrics(b, "fleet", item)
    drained = broker.drain_host(b)["fleet"]
    assert len(drained) == 1
    rec = drained[0]
    assert isinstance(rec["loss"], float) and rec["loss"] == 0.5
    assert rec["per_scenario_return"] == [1.0, 2.0, 3.0]
    json.dumps(rec)  # JSON-serializable as drained
    host = _host_record(rec)
    assert host["per_scenario_return"] == [1.0, 2.0, 3.0]
    assert isinstance(host["loss"], float)


# --- scheduler _partition edge cases -------------------------------------------
def test_partition_min_envs_overshoot_shaved():
    """When the min_envs floor overshoots `total`, the largest members are
    shaved back (never below min_envs) until the budget holds."""
    # weights push everything to member 0; min_envs floors 1 and 2 up
    counts = scheduler._partition([100.0, 1.0, 1.0], 6, 2)
    assert sum(counts) == 6
    assert all(c >= 2 for c in counts)
    assert counts[0] == 2  # shaved from its raw share down to the budget


def test_partition_tie_break_by_position():
    """Equal weights with a non-divisible total: the remainder goes to the
    EARLIEST members (stable position tie-break, part of the determinism
    contract)."""
    assert scheduler._partition([1.0, 1.0, 1.0], 7, 1) == [3, 2, 2]
    assert scheduler._partition([1.0, 1.0, 1.0, 1.0], 6, 1) == [2, 2, 1, 1]
    # stable across calls
    assert (scheduler._partition([2.0, 1.0], 5, 1)
            == scheduler._partition([2.0, 1.0], 5, 1))


def test_partition_property_sums_and_respects_min():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(
        weights=st.lists(st.floats(min_value=0.01, max_value=100.0,
                                   allow_nan=False, allow_infinity=False),
                         min_size=1, max_size=6),
        extra=st.integers(min_value=0, max_value=40),
        min_envs=st.integers(min_value=1, max_value=3))
    def prop(weights, extra, min_envs):
        total = min_envs * len(weights) + extra
        counts = scheduler._partition(weights, total, min_envs)
        assert sum(counts) == total
        assert all(c >= min_envs for c in counts)

    prop()


# --- single fleet program: conformance to per-scenario dispatch ----------------
def test_super_batch_rollout_bit_identical_to_dispatch(tmp_path):
    """The one-program super-batch rollout, sliced back to real env
    counts, reproduces `Orchestrator.sample_fleet` bit-for-bit per
    scenario at equal seeds (zero padding on a single-`data`-shard mesh;
    the scan bodies are structurally identical by construction)."""
    from repro.fleet import superbatch

    runner = _runner(tmp_path / "conform", n_iterations=1)
    prog = runner.program
    assert prog is not None
    keys = runner._keys(0)
    padded = jax.jit(prog.rollout_super_batch)(runner.params, keys)
    for m in runner.schedule.members:
        ref = runner.forch.orchs[m.name].sample_fleet(runner.params,
                                                      keys[m.name])
        got = superbatch.slice_traj(padded[m.name], m.n_envs)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_single_program_trains_bit_identical_to_dispatch(tmp_path):
    """Three iterations end-to-end: the single-program path and the
    per-scenario dispatch path produce bit-identical params (same seeds,
    same key schedule, same state tree)."""
    results = {}
    for flag in (True, False):
        runner = _runner(tmp_path / f"sp_{flag}", n_iterations=3,
                         single_program=flag)
        runner.train(resume=False)
        results[flag] = jax.device_get(runner.params)
    for got, want in zip(jax.tree.leaves(results[True]),
                         jax.tree.leaves(results[False])):
        np.testing.assert_array_equal(got, want)

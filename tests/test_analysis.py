"""Red-team tests for the static-analysis gate (`repro.analysis`).

Every rule id in `report.RULES` is exercised against deliberately
violating code — the analyzers are tested against known-bad programs,
not just the (clean) repo — plus clean-tree certification tests that
pin the repo itself at zero unsuppressed findings.
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ast_rules, cli, jaxpr_audit, kernel_audit, trace_audit
from repro.analysis.entrypoints import Built, EntryPoint
from repro.analysis.report import RULES, Finding, Report


def _rules(findings):
    return {f.rule for f in findings}


def _lint(src, **kw):
    kw.setdefault("hot", True)
    kw.setdefault("kernel_module", False)
    kw.setdefault("registry_names", frozenset({"good_env"}))
    return ast_rules.lint_source("fixture.py", src, **kw)


# --- layer 2: AST rules ------------------------------------------------------
def test_ast001_numpy_in_traced_function():
    src = (
        "import numpy as np\n"
        "import jax\n"
        "def step(u: jax.Array):\n"
        "    return np.tanh(u)\n"
    )
    assert _rules(_lint(src)) == {"AST001"}


def test_ast001_exempt_host_table_builders_and_properties():
    src = (
        "import numpy as np\n"
        "import jax\n"
        "def table(cfg) -> np.ndarray:\n"          # no tracer param
        "    return np.arange(cfg.n)\n"
        "class C:\n"
        "    @property\n"
        "    def n_dof(self, u: jax.Array):\n"     # property math
        "        return np.prod(self.shape)\n"
    )
    assert _lint(src) == []


def test_ast001_silent_in_cold_modules():
    src = "import numpy as np\nimport jax\ndef f(u: jax.Array):\n    return np.abs(u)\n"
    assert _lint(src, hot=False) == []


def test_ast002_python_random():
    src = (
        "import random\n"
        "import jax\n"
        "def draw(u: jax.Array):\n"
        "    return random.random() + u\n"
    )
    assert _rules(_lint(src)) == {"AST002"}


def test_ast003_unwrapped_np_table_scalar():
    src = (
        "import numpy as np\n"
        "import jax\n"
        "_RK_A = np.array([0.0, 1.0])\n"
        "def substep(du: jax.Array, stage: int):\n"
        "    return _RK_A[stage] * du\n"
    )
    assert _rules(_lint(src)) == {"AST003"}


def test_ast003_float_wrap_is_clean():
    src = (
        "import numpy as np\n"
        "import jax\n"
        "_RK_A = np.array([0.0, 1.0])\n"
        "def substep(du: jax.Array, stage: int):\n"
        "    return float(_RK_A[stage]) * du\n"
    )
    assert _lint(src) == []


def test_ast004_jnp_float64():
    src = "import jax.numpy as jnp\nx = jnp.zeros((3,), jnp.float64)\n"
    assert _rules(_lint(src)) == {"AST004"}


def test_ast005_concrete_interpret_default():
    src = "def my_kernel(u, *, interpret: bool = True):\n    return u\n"
    assert _rules(_lint(src, kernel_module=True)) == {"AST005"}
    ok = "def my_kernel(u, *, interpret=None):\n    return u\n"
    assert _lint(ok, kernel_module=True) == []


def test_ast006_unregistered_env_name():
    src = "from repro import envs\nenv = envs.make('not_a_scenario')\n"
    assert _rules(_lint(src)) == {"AST006"}
    assert _lint("from repro import envs\nenv = envs.make('good_env')\n") == []


def test_ast007_suppression_requires_reason():
    src = (
        "import numpy as np\n"
        "import jax\n"
        "def step(u: jax.Array):\n"
        "    return np.tanh(u)  # repro-lint: disable=AST001\n"
    )
    rules = _rules(_lint(src))
    assert "AST007" in rules          # reasonless suppression is a finding
    assert "AST001" in rules          # ...and does NOT suppress


def test_suppression_with_reason_suppresses():
    src = (
        "import numpy as np\n"
        "import jax\n"
        "def step(u: jax.Array):\n"
        "    return np.tanh(u)  # repro-lint: disable=AST001 -- trace-time table\n"
    )
    findings = _lint(src)
    assert [f.rule for f in findings] == ["AST001"]
    assert findings[0].suppressed and findings[0].suppress_reason


# --- layer 1: jaxpr audit ----------------------------------------------------
def _audit(fn, args, **built_kw):
    built = Built(fn=fn, args=args, **built_kw)
    return jaxpr_audit.audit_entry(EntryPoint("fixture", lambda: built), built)


def test_jax001_f64_promotion():
    from jax.experimental import enable_x64

    with enable_x64():
        findings = _audit(lambda u: u.astype(jnp.float64) * 2.0,  # repro-lint: disable=AST004 -- deliberate f64 red-team fixture
                          (jnp.zeros((4,), jnp.float32),))
    assert "JAX001" in _rules(findings)


def test_jax002_bf16_interval_churn():
    def churned(u):
        d = jnp.ones((8, 8), jnp.float32)     # un-cast f32 operator

        def body(u, _):
            v = jnp.einsum("ij,jk->ik", d, u.astype(jnp.float32))
            rhs = v + 0.5 * v                 # elementwise f32 chain
            return u + rhs.astype(jnp.bfloat16) * 0.1, None

        u, _ = jax.lax.scan(body, u, None, length=3)
        return u

    u = jnp.zeros((8, 64), jnp.bfloat16)
    findings = _audit(churned, (u,), bf16_interval=True, state_size=u.size)
    assert "JAX002" in _rules(findings)


def test_jax002_reduction_upcast_is_clean():
    def accum(u):
        def body(u, _):
            # f32 accumulator of a bf16 sum: the intended mixed-precision
            # pattern — demoting it back must NOT count as churn
            e = jnp.sum(u.astype(jnp.float32) ** 2)
            return u * (1.0 - 1e-6 * e.astype(jnp.bfloat16)), None

        u, _ = jax.lax.scan(body, u, None, length=3)
        return u

    u = jnp.zeros((8, 64), jnp.bfloat16)
    findings = _audit(accum, (u,), bf16_interval=True, state_size=u.size)
    assert "JAX002" not in _rules(findings)


def test_jax003_host_callback():
    def with_callback(u):
        return jax.pure_callback(
            lambda x: x, jax.ShapeDtypeStruct(u.shape, u.dtype), u)

    findings = _audit(with_callback, (jnp.zeros((4,), jnp.float32),))
    assert "JAX003" in _rules(findings)


def test_jax004_dropped_donation():
    fn = lambda u: u + 1.0
    u = jnp.zeros((8,), jnp.float32)
    undonated = jax.jit(fn)                       # forgot donate_argnums
    findings = _audit(fn, (u,), jit_fn=undonated, expect_aliased=1)
    assert "JAX004" in _rules(findings)
    donated = jax.jit(fn, donate_argnums=(0,))
    assert _audit(fn, (u,), jit_fn=donated, expect_aliased=1) == []


def test_jax005_large_undonated_outputs():
    fn = lambda u: u * 2.0
    u = jnp.zeros((1 << 18,), jnp.float32)        # 1 MiB output, not donated
    findings = _audit(fn, (u,), jit_fn=jax.jit(fn), max_undonated_mb=0.5)
    assert "JAX005" in _rules(findings)


# --- layer 1: trace audit ----------------------------------------------------
def test_trace001_retrace_on_every_call():
    @jax.jit
    def f(u):
        return u * 2

    with trace_audit.watch({"f": f}) as w:
        f(jnp.zeros((3,)))
        f(jnp.zeros((4,)))                        # new shape -> retrace
    findings = w.check({"f": 1})
    assert [x.rule for x in findings] == ["TRACE001"]
    assert "retrace" in findings[0].message

    with trace_audit.watch({"f": f}) as w:
        f(jnp.zeros((3,)))                        # cached: zero growth
    assert w.check({"f": 0}) == []


def test_trace_certify_raises_on_mismatch():
    @jax.jit
    def g(u):
        return u + 1

    with pytest.raises(RuntimeError, match="trace certification failed"):
        trace_audit.certify({"g": g}, {"g": 1},
                            lambda: (g(jnp.zeros((2,))), g(jnp.zeros((3,)))))


def test_trace_watch_rejects_unjitted():
    with pytest.raises(TypeError, match="not a jitted callable"):
        trace_audit.watch({"f": lambda u: u})


# --- layer 1: kernel audit ---------------------------------------------------
def test_kern001_captured_array_constant():
    from jax.experimental import pallas as pl

    table = jnp.arange(8.0)                       # closure-captured array

    def bad_kernel(u_ref, o_ref):
        o_ref[...] = u_ref[...] * table

    def bad(u):
        return pl.pallas_call(
            bad_kernel,
            out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
            interpret=True)(u)

    findings, _ = kernel_audit.audit_kernel(
        "bad", bad, (jnp.zeros((8,), jnp.float32),), {})
    assert "KERN001" in _rules(findings)


def test_kern002_block_does_not_divide():
    from jax.experimental import pallas as pl

    def kern(u_ref, o_ref):
        o_ref[...] = u_ref[...] * 2

    def bad(u):
        return pl.pallas_call(
            kern,
            grid=(3,),
            in_specs=[pl.BlockSpec((4,), lambda i: (i,))],
            out_specs=pl.BlockSpec((4,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
            interpret=True)(u)

    findings, _ = kernel_audit.audit_kernel(
        "bad", bad, (jnp.zeros((10,), jnp.float32),), {})  # 4 !| 10
    assert "KERN002" in _rules(findings)


def test_kern003_vmem_budget():
    from repro.analysis.kernel_audit import _kernel_cases

    fn, args, kwargs = _kernel_cases()["dg_derivative3"]()
    findings, meta = kernel_audit.audit_kernel(
        "dg_derivative3", fn, args, kwargs, vmem_budget_mb=1e-6)
    assert "KERN003" in _rules(findings)
    assert meta["vmem_mb"] > 0


# --- the repo itself must be clean -------------------------------------------
def test_repo_ast_lint_clean():
    report = ast_rules.run(root=".")
    assert report.clean, report.summary()


def test_repo_kernel_audit_clean():
    report = kernel_audit.run()
    assert report.clean, report.summary()


def test_repo_jaxpr_audit_clean_and_bf16_interval_certified():
    report = jaxpr_audit.run()
    assert report.clean, report.summary()
    # the acceptance criterion: both bf16 advance entry points were walked
    audited = report.meta["jaxpr_audit"]["entrypoints"]
    assert "hit_advance_bf16" in audited and "channel_advance_bf16" in audited


def test_repo_trace_certification():
    report = trace_audit.run()
    assert report.clean, report.summary()
    counts = report.meta["trace_audit"]["reduced_hit_compile_counts"]
    assert counts == trace_audit.EXPECTED_REDUCED_HIT


# --- report / CLI plumbing ---------------------------------------------------
def test_report_schema_roundtrip(tmp_path):
    rep = Report(findings=[
        Finding(rule="AST001", message="m", file="f.py", line=3),
        Finding(rule="JAX002", message="s", entrypoint="e",
                suppressed=True, suppress_reason="why"),
    ])
    path = rep.save(str(tmp_path / "r.json"))
    data = json.loads(open(path).read())
    assert data["clean"] is False and data["n_findings"] == 1
    assert data["n_suppressed"] == 1
    assert data["findings_by_rule"] == {"AST001": 1}
    assert all(f["rule"] in RULES for f in data["findings"])


def test_cli_gates_on_findings(tmp_path):
    bad = tmp_path / "src" / "repro" / "envs"
    bad.mkdir(parents=True)
    (bad / "bad.py").write_text(
        "import numpy as np\nimport jax\n"
        "def step(u: jax.Array):\n    return np.tanh(u)\n")
    for sub in ("examples", "benchmarks", "tests"):
        (tmp_path / sub).mkdir()
    report_path = tmp_path / "analysis_report.json"
    rc = cli.main(["--layers", "ast", "--root", str(tmp_path),
                   "--report", str(report_path)])
    assert rc == 1
    assert json.loads(report_path.read_text())["findings_by_rule"] == {
        "AST001": 1}


def test_cli_rejects_unknown_layer():
    with pytest.raises(SystemExit):
        cli.main(["--layers", "nope"])


def test_every_rule_has_a_red_team_test():
    """Meta-test: the assertions above must cover the whole catalog."""
    covered = {
        "AST001", "AST002", "AST003", "AST004", "AST005", "AST006",
        "AST007", "JAX001", "JAX002", "JAX003", "JAX004", "JAX005",
        "TRACE001", "KERN001", "KERN002", "KERN003",
    }
    assert covered == set(RULES)


# --- satellite: REPRO_KERNELS validation -------------------------------------
def test_repro_kernels_env_validation(monkeypatch):
    from repro.kernels import policy

    for ok in ("kernel", "ref", "auto", "", "  KERNEL "):
        monkeypatch.setenv("REPRO_KERNELS", ok)
        policy.default_impl()                     # must not raise
    monkeypatch.setenv("REPRO_KERNELS", "kernels")
    with pytest.raises(ValueError) as e:
        policy.default_impl()
    msg = str(e.value)
    assert "REPRO_KERNELS" in msg and "'kernels'" in msg
    for accepted in ("kernel", "ref", "auto"):
        assert f"'{accepted}'" in msg

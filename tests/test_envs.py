"""Env-protocol conformance suite + numerical-identity regressions.

One parametrized contract run against registered environments: specs are
truthful (shapes/dtypes/bounds), every env's DECLARED channel names/scales
match its `observe()` output, `step` is deterministic given
(state, action), the blow-up guard floors the reward and keeps the carried
state sane, and `reset_from_bank` round-trips.  Solver-scale envs
(hit_les_24dof/32dof, burgers_96dof) run the cheap spec/bank checks only;
the reduced envs additionally exercise stepping and full training.

Bit-identity pins: the named-channel `ObsSpec` refactor must not perturb
the legacy scenarios — HIT and Burgers observations are pinned bit-for-bit
against independent re-derivations of the pre-refactor observation path,
and the HIT rollout against the cfd free functions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import envs
from repro.core import policy as policy_lib, rollout as rollout_lib

ALL = envs.registered()
REDUCED = tuple(n for n in ALL if n.endswith("_reduced"))


def _short(name):
    """Cheap-horizon override so bank/step checks stay fast at any scale."""
    return envs.make(name, t_end=0.2, dt_rl=0.1)


# --- declarative specs ------------------------------------------------------
@pytest.mark.parametrize("name", ALL)
def test_specs_declared_and_hashable(name):
    env = _short(name)
    assert env.obs_spec.shape == (env.obs_spec.n_elements,
                                  *env.obs_spec.spatial,
                                  env.obs_spec.channels)
    assert env.action_spec.low < env.action_spec.high
    assert env.n_actions >= 1
    hash(env)  # envs are static jit values: must be hashable
    assert isinstance(env, envs.Env)


@pytest.mark.parametrize("name", ALL)
def test_channels_declared_by_name(name):
    """Every registered env declares its observation channels by name, with
    usable per-channel normalization scales and policy-input gains."""
    spec = _short(name).obs_spec
    assert len(spec.channel_specs) == spec.channels >= 1
    assert all(isinstance(c, envs.ChannelSpec) for c in spec.channel_specs)
    names = spec.channel_names
    assert len(set(names)) == len(names)  # unique
    assert all(n for n in names)          # non-empty
    # observe() divides each channel by its scale; must be usable
    assert all(s > 0.0 for s in spec.channel_scales)
    assert all(g > 0.0 for g in spec.channel_gains)


def test_legacy_uniform_scale_property():
    """`ObsSpec.scale` survives as a derived property for uniform-scale
    envs and refuses to collapse genuinely mixed per-channel scales."""
    hit = envs.make("hit_les_reduced")
    assert hit.obs_spec.scale == hit.cfg.u_rms
    mixed = envs.make("channel_wm_p_reduced")
    assert mixed.obs_spec.channel_scales[-1] == mixed.cfg.tau_wall
    with pytest.raises(ValueError, match="mixed per-channel scales"):
        mixed.obs_spec.scale


@pytest.mark.parametrize("name", REDUCED)
def test_declared_channels_match_observe(name):
    """Conformance: the declared channel tuple is truthful about observe()
    — channel count matches the trailing axis and the spec validates the
    produced observation (batched and unbatched)."""
    env = envs.make(name)
    spec = env.obs_spec
    bank = env.initial_state_bank(jax.random.PRNGKey(7), 2)
    state, obs = env.reset_from_bank(bank, jnp.asarray(0))
    assert obs.shape[-1] == len(spec.channel_names)
    spec.validate(obs)
    spec.validate(env.observe(state._replace(u=bank)))  # bank-batched


@pytest.mark.parametrize("name", REDUCED)
def test_bank_reset_roundtrip_and_obs_spec(name):
    env = envs.make(name)
    bank = env.initial_state_bank(jax.random.PRNGKey(0), 3)
    assert bank.shape[0] == 3
    assert bool(jnp.all(jnp.isfinite(bank)))
    state, obs = env.reset_from_bank(bank, jnp.asarray(1))
    np.testing.assert_array_equal(np.asarray(state.u), np.asarray(bank[1]))
    assert int(state.t_step) == 0
    assert obs.shape == env.obs_spec.shape
    assert obs.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(obs),
                                  np.asarray(env.observe(state)))


# --- stepping contract ------------------------------------------------------
def _mid_action(env):
    spec = env.action_spec
    return jnp.full(spec.shape, 0.5 * (spec.low + spec.high), jnp.float32)


@pytest.mark.parametrize("name", REDUCED)
def test_step_shapes_dtypes_and_bounds(name):
    env = envs.make(name)
    bank = env.initial_state_bank(jax.random.PRNGKey(1), 2)
    state, _ = env.reset_from_bank(bank, jnp.asarray(0))
    res = jax.jit(env.step)(state, _mid_action(env))
    assert res.obs.shape == env.obs_spec.shape
    assert res.reward.shape == () and res.reward.dtype == jnp.float32
    assert res.done.dtype == jnp.bool_
    assert res.state.u.shape == state.u.shape
    assert -1.0 <= float(res.reward) <= 1.0
    assert bool(jnp.all(jnp.isfinite(res.state.u)))


@pytest.mark.parametrize("name", REDUCED)
def test_step_deterministic(name):
    env = envs.make(name)
    bank = env.initial_state_bank(jax.random.PRNGKey(2), 2)
    state, _ = env.reset_from_bank(bank, jnp.asarray(1))
    action = _mid_action(env)
    r1 = env.step(state, action)
    r2 = env.step(state, action)
    np.testing.assert_array_equal(np.asarray(r1.state.u), np.asarray(r2.state.u))
    np.testing.assert_array_equal(np.asarray(r1.reward), np.asarray(r2.reward))


@pytest.mark.parametrize("name", REDUCED)
def test_blowup_guard(name):
    """A non-finite advance reverts the transition and floors the reward at
    -1 — fleet-wide fault tolerance is part of the env contract."""
    env = envs.make(name)
    bank = env.initial_state_bank(jax.random.PRNGKey(3), 2)
    state, _ = env.reset_from_bank(bank, jnp.asarray(0))
    poisoned = state._replace(u=state.u.at[(0,) * state.u.ndim].set(jnp.nan))
    res = jax.jit(env.step)(poisoned, _mid_action(env))
    assert float(res.reward) == -1.0
    np.testing.assert_array_equal(np.asarray(res.state.u),
                                  np.asarray(poisoned.u))


@pytest.mark.parametrize("name", REDUCED)
def test_policy_heads_from_specs(name):
    env = envs.make(name)
    pcfg = policy_lib.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
    params = policy_lib.init(jax.random.PRNGKey(4), pcfg)
    bank = env.initial_state_bank(jax.random.PRNGKey(5), 2)
    obs = jnp.stack([env.reset_from_bank(bank, jnp.asarray(i))[1]
                     for i in range(2)])
    mean = policy_lib.actor_mean(params, pcfg, obs)
    assert mean.shape == (2,) + env.action_spec.shape
    assert bool(jnp.all(mean >= env.action_spec.low))
    assert bool(jnp.all(mean <= env.action_spec.high))
    val = policy_lib.value(params, pcfg, obs)
    assert val.shape == (2,)


# --- pre-refactor observation bit-identity ----------------------------------
def test_hit_obs_bit_identical_to_prerefactor():
    """The named-channel refactor leaves HIT observations bit-identical to
    the pre-refactor path: per-element velocity nodes over u_rms, derived
    here independently of the env/spec machinery."""
    from repro.cfd.equations import conservative_to_primitive

    env = envs.make("hit_les_reduced")
    cfg = env.cfg
    bank = env.initial_state_bank(jax.random.PRNGKey(11), 3)
    state, obs = env.reset_from_bank(bank, jnp.asarray(2))
    _, vel, _, _ = conservative_to_primitive(state.u)
    k, n = cfg.n_elem, cfg.n_poly + 1
    want = vel.reshape((k**3, n, n, n, 3)) / cfg.u_rms
    np.testing.assert_array_equal(np.asarray(obs), np.asarray(want))


def test_burgers_obs_bit_identical_to_prerefactor():
    """Same pin for Burgers: observation is exactly u / u_rms."""
    env = envs.make("burgers_reduced")
    bank = env.initial_state_bank(jax.random.PRNGKey(12), 3)
    state, obs = env.reset_from_bank(bank, jnp.asarray(1))
    np.testing.assert_array_equal(np.asarray(obs),
                                  np.asarray(state.u / env.cfg.u_rms))


def test_channel_p_extends_channel_wm_obs():
    """`channel_wm_p` is the base channel observation plus one channel: its
    first three channels are bit-identical to `channel_wm` on the same
    state, and the fourth is the tau_wall-normalized pressure fluctuation."""
    base = envs.make("channel_wm_reduced")
    rich = envs.make("channel_wm_p_reduced")
    assert base.cfg == rich.cfg
    bank = base.initial_state_bank(jax.random.PRNGKey(13), 2)
    state, obs3 = base.reset_from_bank(bank, jnp.asarray(0))
    _, obs4 = rich.reset_from_bank(bank, jnp.asarray(0))
    np.testing.assert_array_equal(np.asarray(obs4[..., :3]),
                                  np.asarray(obs3))
    # after a step the pressure channel carries real fluctuations
    res = jax.jit(rich.step)(state, jnp.full(rich.action_spec.shape, 1.0,
                                             jnp.float32))
    p_chan = np.asarray(res.obs[..., 3])
    assert np.all(np.isfinite(p_chan))
    assert p_chan.std() > 0.0


def test_policy_gains_from_declared_channels():
    """from_specs threads declared per-channel gains into the trunk input;
    unity gains collapse to None (the identity — no graph change)."""
    hit = envs.make("hit_les_reduced")
    pcfg = policy_lib.PolicyConfig.from_specs(hit.obs_spec, hit.action_spec)
    assert pcfg.in_gains == (1.0, 1.0, 1.0) and pcfg.active_gains is None

    rich = envs.make("channel_wm_p_reduced")
    pcfg4 = policy_lib.PolicyConfig.from_specs(rich.obs_spec,
                                               rich.action_spec)
    assert pcfg4.channels == 4
    assert pcfg4.active_gains == (1.0, 1.0, 1.0, 0.5)
    # the gain really reaches the trunk input: doubling the pressure gain
    # changes the actor output on a pressure-carrying observation
    params = policy_lib.init(jax.random.PRNGKey(14), pcfg4)
    bank = rich.initial_state_bank(jax.random.PRNGKey(15), 2)
    state, _ = rich.reset_from_bank(bank, jnp.asarray(0))
    obs = rich.step(state, jnp.full(rich.action_spec.shape, 1.0,
                                    jnp.float32)).obs
    boosted = dataclasses.replace(pcfg4, in_gains=(1.0, 1.0, 1.0, 1.0e3))
    a, b = (policy_lib.actor_mean(params, c, obs) for c in (pcfg4, boosted))
    assert not np.allclose(np.asarray(a), np.asarray(b))


# --- HIT numerical identity -------------------------------------------------
def test_hit_adapter_rollout_matches_free_functions():
    """The env-protocol rollout of the HIT scenario is bit-identical to a
    direct composition of the pre-refactor cfd free functions."""
    from repro.cfd import env as hit_kernel, spectra

    env = envs.make("hit_les_reduced")
    cfg = env.cfg
    pcfg = policy_lib.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
    params = policy_lib.init(jax.random.PRNGKey(0), pcfg)
    u0 = env.initial_state_bank(jax.random.PRNGKey(1), 2)
    key = jax.random.PRNGKey(2)

    traj = jax.jit(lambda p, u, k: rollout_lib.rollout(p, pcfg, env, u, k)
                   )(params, u0, key)

    # reference: the same scan hard-wired to the cfd free functions.  The
    # action noise is pre-drawn as scan data from the identical key stream
    # — rollout()'s structural contract (see its docstring): drawing inside
    # the scan instead changes XLA's FMA fusion of `mean + std * noise` at
    # the ulp level, so the reference must draw the same way.
    e_dns = jnp.asarray(spectra.reference_spectrum(cfg), jnp.float32)

    def reference(params, u0, key):
        state0 = hit_kernel.EnvState(
            u=u0, t_step=jnp.zeros((u0.shape[0],), jnp.int32))
        step_keys = jax.random.split(key, cfg.n_actions)
        noise = jax.vmap(lambda kk: jax.random.normal(
            kk, (u0.shape[0],) + env.action_spec.shape))(step_keys)

        def step_fn(state, noise_t):
            obs = hit_kernel.observe(state.u, cfg)
            mean, std = policy_lib.distribution(params, pcfg, obs)
            action = mean + std * noise_t
            logp = policy_lib.log_prob(mean, std, action)
            val = policy_lib.value(params, pcfg, obs)
            res = hit_kernel.step(state, action, cfg, e_dns)
            return res.state, (obs, action, logp, res.reward, val)

        return jax.lax.scan(step_fn, state0, noise)

    _, (obs, actions, log_probs, rewards, values) = jax.jit(reference)(
        params, u0, key)
    for got, want in ((traj.obs, obs), (traj.actions, actions),
                      (traj.log_probs, log_probs), (traj.rewards, rewards),
                      (traj.values, values)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- end-to-end: both scenarios through the SAME runner ----------------------
@pytest.mark.parametrize("name", REDUCED)
def test_train_through_unchanged_runner(name, tmp_path):
    """Acceptance: every registered reduced scenario trains >= 3 iterations
    through the identical Runner code path with finite losses."""
    from repro.core.orchestrator import FleetConfig
    from repro.core.runner import Runner, RunnerConfig

    runner = Runner(
        envs.make(name), FleetConfig(n_envs=2, bank_size=4),
        run_cfg=RunnerConfig(n_iterations=3, eval_every=2, checkpoint_every=10,
                             checkpoint_dir=str(tmp_path / name),
                             async_checkpoint=False),
    )
    history = runner.train(resume=False)
    assert len(history) == 3
    for rec in history:
        assert np.isfinite(rec["return_norm"])
        assert np.isfinite(rec["ppo/loss"])
        assert -1.0 <= rec["return_norm"] <= 1.0
    assert any("eval_return_norm" in r for r in history)

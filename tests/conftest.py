import os

# Tests run on the single real CPU device — the 512-device override belongs
# ONLY to launch/dryrun.py (see the brief).  Keep allocation deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_enable_fast_math=false")

import jax

jax.config.update("jax_enable_x64", False)

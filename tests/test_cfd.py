"""CFD substrate physics tests: DG operators, NS solver invariants, spectra."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import dgsem, equations, initial, solver, spectra
from repro.cfd.dgsem import DGParams
from repro.cfd.solver import HITConfig

CFG = HITConfig(n_poly=3, n_elem=2, k_max=3, alpha=0.4, t_end=0.2, dt_rl=0.1,
                k_peak=2.0, k_eta=8.0)


# --- GLL / DG operators -------------------------------------------------------
def test_gll_weights_integrate_constants():
    from repro.cfd import gll
    for n in (1, 3, 5, 7):
        x, w = gll.gll_nodes_weights(n)
        assert np.isclose(np.sum(w), 2.0)
        # GLL rule integrates polynomials up to degree 2n-1 exactly
        for deg in range(2 * n - 1):
            exact = (1 - (-1) ** (deg + 1)) / (deg + 1)
            assert np.isclose(np.sum(w * x**deg), exact, atol=1e-12), deg


def test_derivative_matrix_polynomial_exactness():
    from repro.cfd import gll
    n = 5
    x, _ = gll.gll_nodes_weights(n)
    d = gll.lagrange_derivative_matrix(n)
    for deg in range(n + 1):
        np.testing.assert_allclose(d @ x**deg,
                                   deg * x ** max(deg - 1, 0) if deg else 0 * x,
                                   atol=1e-10)


def test_dg_gradient_of_linear_field():
    """The DG gradient of a (periodic-compatible) trig field converges;
    for a field constant along a direction the gradient is ~0 there."""
    dg = DGParams(4, 3)
    ops = {"D": jnp.asarray(dg.deriv_matrix(), jnp.float32)}
    _, w = dg.nodes_weights()
    inv_w = (float(1.0 / w[0]), float(1.0 / w[-1]))
    coords = dg.node_coords()  # (K, n)
    x = jnp.asarray(coords)[:, None, None, :, None, None]
    x = jnp.broadcast_to(x, (3, 3, 3, 5, 5, 5))[..., None]
    q = jnp.sin(x)  # varies along direction 0 only
    grad = dgsem.dg_gradient(q, dg, ops["D"], inv_w)
    # direction 0: N=4 interpolation of sin over 2pi/3 elements -> ~1e-2
    np.testing.assert_allclose(np.asarray(grad[..., 0, 0]),
                               np.asarray(jnp.cos(x)[..., 0]), atol=2e-2)
    np.testing.assert_allclose(np.asarray(grad[..., 0, 1]), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(grad[..., 0, 2]), 0.0, atol=1e-4)


# --- solver invariants -----------------------------------------------------------
def _uniform_state(cfg, vel=(0.3, -0.2, 0.1)):
    dg = cfg.dg
    n = cfg.n_poly + 1
    shape = (cfg.n_elem,) * 3 + (n,) * 3
    rho = jnp.full(shape, cfg.rho0, jnp.float32)
    v = jnp.broadcast_to(jnp.asarray(vel, jnp.float32), shape + (3,))
    p = jnp.full(shape, cfg.p0, jnp.float32)
    return equations.primitive_to_conservative(rho, v, p)


def test_free_stream_preservation():
    """A uniform flow must stay exactly uniform (well-balancedness)."""
    u0 = _uniform_state(CFG)
    cs = 0.1 * jnp.ones((CFG.n_elem,) * 3, jnp.float32)
    u1 = solver.advance_rl_interval(u0, cs, CFG)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u0),
                               rtol=1e-5, atol=1e-5)


def test_conservation_without_forcing():
    """Mass and momentum means are conserved by the DG divergence."""
    cfg = dataclasses.replace(CFG, forcing_a0=0.0)
    u0 = initial.sample_initial_state(jax.random.PRNGKey(0), cfg)
    cs = 0.17 * jnp.ones((cfg.n_elem,) * 3, jnp.float32)
    u1 = solver.advance_rl_interval(u0, cs, cfg)
    m0 = dgsem.quadrature_mean(u0, cfg.dg)
    m1 = dgsem.quadrature_mean(u1, cfg.dg)
    np.testing.assert_allclose(float(m1[0]), float(m0[0]), rtol=1e-6)  # mass
    np.testing.assert_allclose(np.asarray(m1[1:4]), np.asarray(m0[1:4]),
                               atol=1e-6)  # momentum
def test_energy_decays_without_forcing():
    """Viscosity + SGS must drain kinetic energy in decaying HIT."""
    cfg = dataclasses.replace(CFG, forcing_a0=0.0)
    u0 = initial.sample_initial_state(jax.random.PRNGKey(1), cfg)
    cs = 0.17 * jnp.ones((cfg.n_elem,) * 3, jnp.float32)
    u1 = solver.advance_rl_interval(u0, cs, cfg)

    def ke(u):
        rho, vel, _, _ = equations.conservative_to_primitive(u)
        e = 0.5 * rho * jnp.sum(vel**2, -1)
        return float(dgsem.quadrature_mean(e[..., None], cfg.dg)[0])

    assert ke(u1) < ke(u0)


def test_solver_stability_many_steps():
    u = initial.sample_initial_state(jax.random.PRNGKey(2), CFG)
    cs = 0.1 * jnp.ones((CFG.n_elem,) * 3, jnp.float32)
    for _ in range(3):
        u = solver.advance_rl_interval(u, cs, CFG)
    assert bool(jnp.all(jnp.isfinite(u)))


# --- initial states / spectra -------------------------------------------------------
def test_initial_state_divergence_free():
    """The Rogallo sampler's velocity is solenoidal (spectral check)."""
    n = 16
    n_shells = spectra._shell_bins(n)[1]
    e_target = jnp.asarray(
        spectra.vkp_spectrum(np.arange(n_shells), 1.0, 3.0, 7.0), jnp.float32)
    vel = initial._solenoidal_spectral_field(jax.random.PRNGKey(3), n, e_target)
    vhat = jnp.fft.rfftn(vel, axes=(0, 1, 2))
    k1 = np.fft.fftfreq(n, 1.0 / n)
    kr = np.fft.rfftfreq(n, 1.0 / n)
    kx, ky, kz = np.meshgrid(k1, k1, kr, indexing="ij")
    div = (vhat[..., 0] * kx + vhat[..., 1] * ky + vhat[..., 2] * kz)
    denom = np.sqrt(np.mean(np.abs(vhat) ** 2)) * np.sqrt((kx**2+ky**2+kz**2).mean())
    assert float(jnp.max(jnp.abs(div))) / max(denom, 1e-30) < 1e-4


def test_initial_state_matches_target_spectrum():
    """At the paper's 24-DOF resolution the sampled state reproduces the
    target spectrum away from the grid cutoff (GLL interpolation loses a few
    % near Nyquist — the same filtering a real LES restriction applies)."""
    cfg = HITConfig(n_poly=5, n_elem=4, k_max=9)  # paper 24 DOF
    u = initial.sample_initial_state(jax.random.PRNGKey(4), cfg)
    e_les = spectra.les_spectrum(u, cfg)
    e_ref = spectra.reference_spectrum(cfg)
    sl = slice(1, 7)
    np.testing.assert_allclose(np.asarray(e_les)[sl], e_ref[sl], rtol=0.2)


def test_energy_spectrum_single_mode():
    """A pure k=2 Fourier mode lands all its energy in shell 2."""
    n = 16
    x = np.arange(n) * 2 * np.pi / n
    vel = np.zeros((n, n, n, 3), np.float32)
    vel[..., 1] = np.sin(2 * x)[:, None, None]  # v_y(x): div-free
    spec = np.asarray(spectra.energy_spectrum(jnp.asarray(vel)))
    assert np.argmax(spec) == 2
    np.testing.assert_allclose(spec.sum(), 0.5 * np.mean(vel**2) * 3, rtol=1e-5)
    np.testing.assert_allclose(spec[2], spec.sum(), rtol=1e-5)


def test_nodal_uniform_roundtrip():
    """Low-mode field: corner-grid samples -> GLL (exact Fourier eval) ->
    CELL-CENTERED uniform grid (polynomial interpolation).  The output grid
    is offset half a cell from the input grid (nodal_to_uniform emits the
    FFT-ready center grid), so compare against the analytic field evaluated
    at the centers, to polynomial-interpolation accuracy."""
    cfg = HITConfig(n_poly=5, n_elem=4)  # 24^3: degree-5 over pi/2 elements
    n_grid = cfg.dg.n_dof_dir

    def field(x, y, z):
        return np.cos(x) + 0.5 * np.sin(y + 0.3) * np.cos(2 * z)

    x = np.arange(n_grid) * 2 * np.pi / n_grid
    xx, yy, zz = np.meshgrid(x, x, x, indexing="ij")
    f = jnp.asarray(field(xx, yy, zz)[..., None], jnp.float32)
    nodal = initial.uniform_to_gll(f, cfg)
    back = spectra.nodal_to_uniform(nodal, cfg.dg)
    xc = (np.arange(n_grid) + 0.5) * 2 * np.pi / n_grid
    xxc, yyc, zzc = np.meshgrid(xc, xc, xc, indexing="ij")
    want = field(xxc, yyc, zzc)[..., None]
    # degree-5 interpolation of the k=2 mode over pi/2 elements: ~1e-3
    np.testing.assert_allclose(np.asarray(back), want, atol=5e-3)


def test_env_blowup_guard():
    """A non-finite solver state must revert the transition and floor the
    reward at -1 (in-graph fault tolerance; see env.step docstring)."""
    from repro.cfd import env as env_lib
    cfg = CFG
    e_dns = jnp.asarray(spectra.reference_spectrum(cfg), jnp.float32)
    u0 = initial.sample_initial_state(jax.random.PRNGKey(7), cfg)
    # poison the state so ANY advance produces NaN
    u_bad = u0.at[0, 0, 0, 0, 0, 0, 0].set(jnp.nan)
    state = env_lib.EnvState(u=u_bad, t_step=jnp.zeros((), jnp.int32))
    action = 0.1 * jnp.ones((cfg.n_elem**3,), jnp.float32)
    res = jax.jit(lambda s, a: env_lib.step(s, a, cfg, e_dns))(state, action)
    assert float(res.reward) == -1.0
    # the carried state is the (reverted) pre-step state, not NaN...
    np.testing.assert_array_equal(np.asarray(res.state.u), np.asarray(u_bad))
    # ...and a healthy state is untouched by the guard
    state_ok = env_lib.EnvState(u=u0, t_step=jnp.zeros((), jnp.int32))
    res_ok = jax.jit(lambda s, a: env_lib.step(s, a, cfg, e_dns))(state_ok,
                                                                 action)
    assert bool(jnp.isfinite(res_ok.reward))
    assert bool(jnp.all(jnp.isfinite(res_ok.state.u)))


# --- reward ---------------------------------------------------------------------------
def test_reward_bounds_and_perfect_match():
    e = jnp.asarray(spectra.reference_spectrum(CFG), jnp.float32)
    ell = spectra.spectral_error(e, e, CFG.k_max)
    assert float(ell) == 0.0
    assert float(spectra.reward_from_error(ell, CFG.alpha)) == pytest.approx(1.0)
    bad = spectra.spectral_error(2.0 * e, e, CFG.k_max)
    r = float(spectra.reward_from_error(bad, CFG.alpha))
    assert -1.0 <= r < 1.0

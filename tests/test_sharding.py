"""Distribution tests that run on the single real device: logical-axis
rules, flash-decode combine vs the oracle, compressed collectives, and the
orchestrator's fleet layout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import api, attention
from repro.parallel import sharding as shd


def test_default_rules_cover_model_axes():
    for name in ("batch", "embed", "heads", "mlp", "experts", "vocab",
                 "kv_seq", "act_seq"):
        assert name in shd.DEFAULT_RULES


def test_constrain_noop_without_context():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    assert y is x


def test_constrain_applies_spec_on_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, shd.axis_rules(mesh):
        y = jax.jit(lambda x: shd.constrain(x, "batch", "mlp"))(
            jnp.ones((4, 8)))
    assert y.shape == (4, 8)


def test_param_specs_2d_weight():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = shd.AxisRules(mesh)
    params = {"w": jnp.ones((8, 16))}
    axes = {"w": ("embed", "mlp")}
    specs = shd.param_specs(params, axes, rules)
    assert specs["w"] == P("data", "model")


def test_param_specs_nondivisible_falls_back():
    # AbstractMesh: divisibility logic only needs mesh.shape
    mesh = shd.abstract_mesh((1, 2), ("data", "model"))
    rules = shd.AxisRules(mesh)
    specs = shd.param_specs({"w": jnp.ones((8, 25))}, {"w": ("embed", "heads")},
                            rules)
    assert specs["w"] == P("data", None)  # 25 heads don't divide model=2


def test_flash_decode_combine_matches_oracle():
    """decode_combine="flash" (shard_map partial-softmax merge) must equal
    the dense decode path."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              dtype="float32", window=0, window_pattern=0,
                              decode_combine="flash")
    params_a = attention.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model),
                          jnp.float32)
    cache = attention.init_cache(cfg, b, s, window=None, dtype=jnp.float32)
    # warm the cache with some keys
    kx = jax.random.normal(jax.random.PRNGKey(2), (b, cfg.kv_heads, s, cfg.hd))
    vx = jax.random.normal(jax.random.PRNGKey(3), (b, cfg.kv_heads, s, cfg.hd))
    cache = {"k": kx.at[:, :, 5:].set(0), "v": vx.at[:, :, 5:].set(0),
             "pos": jnp.asarray(5, jnp.int32)}

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, shd.axis_rules(mesh):
        out_flash, c1 = jax.jit(
            lambda p, x, c: attention.decode_attention(
                p, cfg, x, c, window=None, combine="flash"))(params_a, x, cache)
    out_dense, c2 = jax.jit(
        lambda p, x, c: attention.decode_attention(
            p, cfg, x, c, window=None, combine="allgather"))(params_a, x, cache)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_dense),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c1["k"]), np.asarray(c2["k"]))


def test_lower_cell_on_host_mesh():
    """specs.lower_cell works on an arbitrary (1,1) mesh — the dry-run path
    minus the 512-device override."""
    from repro.configs.shapes import ShapeConfig
    from repro.launch import specs
    cfg = configs.get_reduced("h2o-danube-1.8b")
    shape = ShapeConfig("tiny_train", 64, 4, "train")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lowered, meta = specs.lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    from repro.launch.hlo_analysis import cost_analysis_dict
    assert cost_analysis_dict(compiled)["flops"] > 0
    shape_d = ShapeConfig("tiny_decode", 64, 4, "decode")
    lowered, _ = specs.lower_cell(cfg, shape_d, mesh)
    assert lowered.compile() is not None


def test_orchestrator_sharded_fleet():
    from repro.configs import relexi_hit
    from repro.core.orchestrator import FleetConfig, Orchestrator
    mesh = jax.make_mesh((1,), ("data",))
    orch = Orchestrator(relexi_hit.reduced(), FleetConfig(n_envs=2, bank_size=3),
                        mesh=mesh)
    traj = orch.sample_fleet(orch.params_placeholder, jax.random.PRNGKey(0)) \
        if hasattr(orch, "params_placeholder") else None
    # minimal: bank is placed and initial draws respect the env sharding
    u0 = orch.draw_initial_states(jax.random.PRNGKey(0))
    assert u0.shape[0] == 2
    assert bool(jnp.all(jnp.isfinite(u0)))


def test_collective_bytes_parser():
    from repro.launch import hlo_analysis
    hlo = """
  %p = f32[16,128]{1,0} parameter(0)
  %ag = f32[16,2048]{1,0} all-gather(%p), replica_groups={}
  %ar = f32[16,128]{1,0} all-reduce(%p), to_apply=%add
  %cp = f32[16,128]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
"""
    stats = hlo_analysis.collective_bytes(hlo)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == 16 * 2048 * 4
    assert stats.bytes_by_kind["all-reduce"] == 2 * 16 * 128 * 4
    assert stats.bytes_by_kind["collective-permute"] == 16 * 128 * 4


def test_roofline_terms_math():
    from repro.launch import hlo_analysis
    t = hlo_analysis.roofline_terms(
        flops_per_dev=197e12, hbm_bytes_per_dev=0.0, coll_bytes_per_dev=0.0,
        n_chips=1, peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)
    assert t["bound"] == "compute"
    assert t["roofline_fraction"] == pytest.approx(1.0)

"""System-level integration tests: the paper's full loop end to end."""
import json
import os

import jax
import numpy as np

from repro import envs
from repro.core.orchestrator import FleetConfig, Orchestrator
from repro.core.ppo import PPOConfig
from repro.core.runner import Runner, RunnerConfig


def test_full_rl_training_loop(tmp_path):
    """Three synchronous PPO iterations: finite metrics, eval runs,
    checkpoints are written, metrics.jsonl is append-only structured."""
    runner = Runner(
        envs.make("hit_les_reduced"), FleetConfig(n_envs=2, bank_size=4),
        ppo_cfg=PPOConfig(),
        run_cfg=RunnerConfig(n_iterations=3, eval_every=2,
                             checkpoint_every=2,
                             checkpoint_dir=str(tmp_path / "rl"),
                             async_checkpoint=False),
    )
    history = runner.train()
    assert len(history) == 3
    for rec in history:
        assert np.isfinite(rec["return_norm"])
        assert np.isfinite(rec["ppo/loss"])
        assert -1.0 <= rec["return_norm"] <= 1.0  # reward bounds propagate
    assert any("eval_return_norm" in r for r in history)
    lines = [json.loads(l) for l in open(runner.metrics_path)]
    assert len(lines) >= 3
    assert os.path.isdir(os.path.join(str(tmp_path / "rl")))


def test_reward_improves_with_good_actions():
    """Sanity: against the synthetic DNS target, a reasonable constant C_s
    beats an absurd one — the reward surface the agent climbs is real."""
    from repro.core.rollout import constant_action_return
    env = envs.make("hit_les_reduced")
    orch = Orchestrator(env, FleetConfig(n_envs=1, bank_size=3))
    u0 = orch.test_state()

    def episode_return(cs_val):
        return constant_action_return(env, u0, cs_val)

    # an over-dissipative model (C_s = 0.5 everywhere) must score worse
    # than a moderate one on the spectral reward
    assert episode_return(0.1) > episode_return(0.5)


def test_lm_and_rl_share_substrate(tmp_path):
    """The same checkpoint/optimizer/data machinery drives both the paper's
    RL loop and the assigned-architecture LM training (DESIGN.md §5)."""
    from repro import configs, optim
    from repro.core import checkpoints
    from repro.data import TokenStream
    from repro.models import api
    cfg = configs.get_reduced("rwkv6-1.6b")
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adam_init(params)
    stream = TokenStream(cfg, 2, 32)
    step = jax.jit(lambda p, o, b: api.train_step(p, o, b, cfg))
    batch = stream.next()  # fixed batch: loss must descend deterministically
    losses = []
    for _ in range(4):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]
    d = str(tmp_path / "lm")
    checkpoints.save(d, 3, {"params": jax.device_get(params)})
    assert checkpoints.latest_step(d) == 3

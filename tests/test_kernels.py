"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.dg_derivative import dg_derivative3
from repro.kernels.flash_attention import flash_attention
from repro.kernels.linear_scan import linear_scan
from repro.kernels.smagorinsky import smagorinsky_nut


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-5)


# --- flash attention ------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,causal,window,softcap",
    [
        (2, 4, 4, 64, 64, 32, True, None, None),      # MHA causal
        (1, 8, 2, 48, 48, 16, True, None, None),      # GQA, non-pow2 seq
        (2, 4, 2, 32, 32, 32, True, 8, None),         # sliding window
        (1, 4, 4, 32, 32, 16, True, None, 20.0),      # softcap (gemma-2)
        (2, 4, 2, 1, 96, 32, True, None, None),       # decode: q at the end
        (1, 2, 1, 16, 80, 16, True, 24, None),        # decode chunk + window
        (2, 4, 4, 64, 64, 64, False, None, None),     # bidirectional (whisper)
    ],
)
def test_flash_attention_vs_ref(b, hq, hkv, sq, skv, d, causal, window,
                                softcap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=16, block_k=32,
                          interpret=True)
    want = ref.mha(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_matches_chunked_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 40, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 2, 40, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 2, 40, 32), jnp.float32)
    a = ref.mha_chunked(q, k, v, causal=True, block_k=16)
    b_ = ref.mha(q, k, v, causal=True)
    np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-5)


# --- gated linear recurrence -----------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("decay_before_read", [False, True])
@pytest.mark.parametrize(
    "b,t,dk,dv,chunk,with_u,with_s0",
    [
        (2, 32, 16, 16, 8, True, False),
        (1, 40, 8, 24, 16, False, True),   # t % chunk != 0 (padding)
        (3, 16, 32, 8, 64, True, True),    # chunk > t
    ],
)
def test_linear_scan_vs_ref(b, t, dk, dv, chunk, with_u, with_s0,
                            decay_before_read, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    q = jax.random.normal(ks[0], (b, t, dk), dtype)
    k = jax.random.normal(ks[1], (b, t, dk), dtype)
    v = jax.random.normal(ks[2], (b, t, dv), dtype)
    w = jax.random.uniform(ks[3], (b, t, dk), jnp.float32,
                           minval=0.5, maxval=0.999).astype(dtype)
    u = (0.3 * jax.random.normal(ks[4], (dk,), dtype)) if with_u else None
    s0 = (jax.random.normal(ks[5], (b, dk, dv), jnp.float32)
          if with_s0 else None)
    o, s = linear_scan(q, k, v, w, u, s0,
                       decay_before_read=decay_before_read, chunk=chunk,
                       interpret=True)
    o_ref, s_ref = ref.linear_scan(q, k, v, w, u, s0,
                                   decay_before_read=decay_before_read)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 2e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_linear_scan_chunked_ref_matches_sequential():
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (2, 37, 8), jnp.float32)
    k = jax.random.normal(ks[1], (2, 37, 8), jnp.float32)
    v = jax.random.normal(ks[2], (2, 37, 12), jnp.float32)
    w = jax.random.uniform(ks[3], (2, 37, 8), minval=0.6, maxval=0.999)
    for dbr in (False, True):
        o1, s1 = ref.linear_scan_chunked(q, k, v, w, None, None,
                                         decay_before_read=dbr, chunk=8)
        o2, s2 = ref.linear_scan(q, k, v, w, None, None,
                                 decay_before_read=dbr)
        np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-5)


def test_linear_scan_state_chaining():
    """Running two halves with carried state == one full run."""
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    b, t, dk, dv = 1, 32, 8, 8
    q = jax.random.normal(ks[0], (b, t, dk))
    k = jax.random.normal(ks[1], (b, t, dk))
    v = jax.random.normal(ks[2], (b, t, dv))
    w = jax.random.uniform(ks[3], (b, t, dk), minval=0.7, maxval=0.99)
    o_full, s_full = ref.linear_scan(q, k, v, w)
    o1, s1 = ref.linear_scan_chunked(q[:, :16], k[:, :16], v[:, :16],
                                     w[:, :16], chunk=8)
    o2, s2 = ref.linear_scan_chunked(q[:, 16:], k[:, 16:], v[:, 16:],
                                     w[:, 16:], s0=s1, chunk=8)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), o_full,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s2, s_full, rtol=1e-4, atol=1e-5)


# --- dg derivative ----------------------------------------------------------------
@pytest.mark.parametrize("n,c,b,block_b", [(4, 5, 16, 8), (6, 3, 10, 4),
                                           (8, 1, 7, 16)])
def test_dg_derivative3_vs_ref(n, c, b, block_b):
    key = jax.random.PRNGKey(5)
    u = jax.random.normal(key, (b, n, n, n, c), jnp.float32)
    d = jax.random.normal(jax.random.PRNGKey(6), (n, n), jnp.float32)
    outs = dg_derivative3(u, d, block_b=block_b, interpret=True)
    wants = ref.dg_derivative3(u, d)
    for o, w in zip(outs, wants):
        np.testing.assert_allclose(o, w, rtol=2e-4, atol=1e-5)


# --- smagorinsky -------------------------------------------------------------------
@pytest.mark.parametrize("p,block_p", [(17, 8), (2048, 512), (64, 128)])
def test_smagorinsky_vs_ref(p, block_p):
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    grad_v = jax.random.normal(ks[0], (p, 3, 3), jnp.float32)
    cs = jax.random.uniform(ks[1], (p,), minval=0.0, maxval=0.5)
    out = smagorinsky_nut(grad_v, cs, 0.1, block_p=block_p, interpret=True)
    want = ref.smagorinsky_nut(grad_v, cs, 0.1)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=1e-7)


def test_ops_dispatch_gradients():
    """The chunked impls are differentiable end-to-end (training path)."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (1, 2, 16, 8))
    k = jax.random.normal(ks[1], (1, 1, 16, 8))
    v = jax.random.normal(ks[2], (1, 1, 16, 8))

    def f(q):
        return jnp.sum(ops.attention(q, k, v, impl="chunked", block_k=8))

    g = jax.grad(f)(q)
    assert g.shape == q.shape and bool(jnp.all(jnp.isfinite(g)))

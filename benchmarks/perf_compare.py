"""§Perf reproducibility: print baseline-vs-optimized comparisons from the
tagged dry-run artifacts (see EXPERIMENTS.md §Perf artifact index).

    PYTHONPATH=src python -m benchmarks.perf_compare
"""
from __future__ import annotations

import glob
import json
import os

from . import common

DRYRUN_DIR = os.path.join(common.ARTIFACTS, "dryrun")

# (arch, shape, tag) -> short description
COMPARISONS = [
    ("h2o-danube-1.8b", "train_4k", "dp",
     "batch x (data,model) + ZeRO-3 (hillclimb cell 1, iter 1)"),
    ("h2o-danube-1.8b", "train_4k", "dp_noremat", "iter 2 (refuted: memory)"),
    ("h2o-danube-1.8b", "train_4k", "dp_dots", "iter 3 (refuted)"),
    ("h2o-danube-1.8b", "train_4k", "dp_projdots", "iter 4 (partial)"),
    ("h2o-danube-1.8b", "train_4k", "dp_savew", "iter 5 (refuted)"),
    ("command-r-35b", "decode_32k", "flash",
     "flash-decoding + pure-TP serve (hillclimb cell 2)"),
    ("command-r-35b", "decode_32k", "flash_bf16", "+ bf16 serving weights"),
    ("rwkv6-1.6b", "train_4k", "dp", "generality: same relayout"),
    ("gemma2-27b", "decode_32k", "flash", "generality: flash decode"),
    ("llava-next-mistral-7b", "decode_32k", "flash", "generality"),
    ("h2o-danube-1.8b", "long_500k", "flash", "generality"),
]


def _load(arch: str, shape: str, tag: str = "") -> dict | None:
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(DRYRUN_DIR, f"single_{arch}_{shape}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run(quick: bool = True) -> dict:
    common.row("# perf_compare", "arch", "shape", "variant",
               "collective_s", "compute_s", "memory_s", "frac", "note")
    n = 0
    for arch, shape, tag, note in COMPARISONS:
        base, opt = _load(arch, shape), _load(arch, shape, tag)
        if not base or not opt or base["status"] != "ok" \
                or opt["status"] != "ok":
            continue
        for label, rec in (("baseline", base), (tag, opt)):
            t = rec["roofline"]
            common.row("perf", arch, shape, label,
                       f"{t['collective_s']:.4f}", f"{t['compute_s']:.4f}",
                       f"{t['memory_s']:.4f}",
                       f"{t['roofline_fraction']:.3f}",
                       note if label != "baseline" else "")
        n += 1
    if n == 0:
        print("no tagged perf artifacts found; run the §Perf commands in "
              "EXPERIMENTS.md first")
    return {"n_comparisons": n}


if __name__ == "__main__":
    run()

"""§Perf reproducibility: print baseline-vs-optimized comparisons from the
tagged dry-run artifacts (see EXPERIMENTS.md §Perf artifact index).

    PYTHONPATH=src python -m benchmarks.perf_compare
"""
from __future__ import annotations

import glob
import json
import os

from . import common

DRYRUN_DIR = os.path.join(common.ARTIFACTS, "dryrun")

# (arch, shape, tag) -> short description
COMPARISONS = [
    ("h2o-danube-1.8b", "train_4k", "dp",
     "batch x (data,model) + ZeRO-3 (hillclimb cell 1, iter 1)"),
    ("h2o-danube-1.8b", "train_4k", "dp_noremat", "iter 2 (refuted: memory)"),
    ("h2o-danube-1.8b", "train_4k", "dp_dots", "iter 3 (refuted)"),
    ("h2o-danube-1.8b", "train_4k", "dp_projdots", "iter 4 (partial)"),
    ("h2o-danube-1.8b", "train_4k", "dp_savew", "iter 5 (refuted)"),
    ("command-r-35b", "decode_32k", "flash",
     "flash-decoding + pure-TP serve (hillclimb cell 2)"),
    ("command-r-35b", "decode_32k", "flash_bf16", "+ bf16 serving weights"),
    ("rwkv6-1.6b", "train_4k", "dp", "generality: same relayout"),
    ("gemma2-27b", "decode_32k", "flash", "generality: flash decode"),
    ("llava-next-mistral-7b", "decode_32k", "flash", "generality"),
    ("h2o-danube-1.8b", "long_500k", "flash", "generality"),
]


def _load(arch: str, shape: str, tag: str = "") -> dict | None:
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(DRYRUN_DIR, f"single_{arch}_{shape}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run_wall_model(quick: bool = True) -> dict:
    """Kernel-vs-ref timings for the Reichardt wall-model inversion — the
    start of the solver-kernel perf trajectory.  On TPU the kernel column is
    the compiled fused launch; off-TPU it runs in Pallas interpret mode (so
    only the `ref` column is meaningful there — the row is still recorded to
    keep the artifact schema stable across backends).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import default_impl, ops

    from repro.analysis import trace_audit

    backend = jax.default_backend()
    common.row("# perf_wall_model", "backend", "points", "impl", "median_s",
               "note")
    sizes = [4096] if quick else [4096, 65536, 1048576]
    kw = dict(y_m=0.05, nu=5e-3, kappa=0.41, iters=8)
    results, compile_counts = [], {}
    for p in sizes:
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        u_par = jax.random.uniform(ks[0], (p,), minval=1e-3, maxval=3.0)
        rho = jax.random.uniform(ks[1], (p,), minval=0.8, maxval=1.2)
        for impl in ("ref", "kernel"):
            # jit BOTH columns: the kernel wrapper is already jitted, and an
            # eager ref column would record dispatch overhead as kernel wins
            fn = jax.jit(lambda u, r, impl=impl:
                         ops.wall_model_tau(u, r, impl=impl, **kw))
            # published numbers are retrace-certified: the fresh jit must
            # compile exactly once across warmup + timed iterations
            name = f"wall_model_{p}_{impl}"
            t, counts = trace_audit.certify(
                {name: fn}, {name: 1},
                lambda: common.timeit(fn, u_par, rho, warmup=2, iters=5))
            compile_counts.update(counts)
            note = ("interpret-mode (oracle check, not perf)"
                    if impl == "kernel" and backend != "tpu" else "")
            common.row("perf_wall_model", backend, p, impl, f"{t:.6f}", note)
            results.append({"backend": backend, "points": p, "impl": impl,
                            "median_s": t})
    common.save_json("perf_wall_model.json",
                     {"default_impl": default_impl(), "rows": results,
                      "certified_compile_counts": compile_counts})
    return {"n_rows": len(results)}


def run_rhs(quick: bool = True) -> dict:
    """Fused-mega-kernel vs separate-ops vs pure-jnp timings for one full
    Navier-Stokes RHS evaluation (the per-RK-substep unit of work):

      * fused      one Pallas launch (kernels/rhs.py) — compiled on TPU,
                   interpret mode elsewhere (still a single XLA dispatch);
      * separate   the pre-fusion kernel composition: per-stage jitted
                   dispatches with the gradients/nu_t stage running the
                   separate dg_derivative3 + smagorinsky_nut Pallas
                   launches (`solver.kernel_grad_nut`) — per-stage
                   dispatch + HBM round-trips, what the mega-kernel
                   removes;
      * pure_jnp   the staged jnp assembly under one jit — XLA's own
                   fusion, the single-dispatch non-Pallas baseline.

    Writes perf_rhs.json with rows + fused_vs_separate_speedup.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.cfd import initial, solver
    from repro.cfd.solver import HITConfig
    from repro.kernels import default_impl

    from repro.analysis import trace_audit

    backend = jax.default_backend()
    common.row("# perf_rhs", "backend", "case", "impl", "median_s", "note")
    cases = [("hit_reduced", HITConfig(n_poly=3, n_elem=2,
                                       use_kernels=False))]
    if not quick:
        # the paper's 24-DOF-per-direction production HIT mesh
        cases.append(("hit_24dof", HITConfig(n_poly=5, n_elem=4,
                                             use_kernels=False)))
    results, speedups, compile_counts = [], {}, {}
    for name, cfg in cases:
        cfg_k = dataclasses.replace(cfg, use_kernels=True)
        ops_d = cfg.operators()
        u = initial.sample_initial_state(jax.random.PRNGKey(0), cfg)
        cs = jnp.full(u.shape[:-1], 0.17, u.dtype)

        fused_fn = jax.jit(
            lambda u, cs: solver.navier_stokes_rhs(u, cs, cfg_k, ops_d))
        pure_fn = jax.jit(
            lambda u, cs: solver.navier_stokes_rhs(u, cs, cfg, ops_d))

        # separate-ops: every stage its own jitted dispatch, gradients via
        # the pre-fusion dg_derivative3 + smagorinsky Pallas composition —
        # the execution shape `use_kernels=True` had before the mega-kernel
        # (stage boundaries force results through HBM and pay per-launch
        # overhead)
        def _prim(u):
            from repro.cfd import equations
            rho, vel, p, temp = equations.conservative_to_primitive(u)
            q_prim = jnp.concatenate([vel, temp[..., None]], axis=-1)
            return rho, vel, p, u[..., 4] / rho, q_prim
        prim_fn = jax.jit(_prim)
        grad_fn = jax.jit(
            lambda q, cs: solver.kernel_grad_nut(
                q, cs, ops_d["D"], ops_d["inv_w_end"], cfg.delta_filter,
                dg=cfg.dg))
        div_fn = jax.jit(
            lambda u, prim, gp, nt: solver.rhs_divergence(
                u, prim, gp, nt, cfg, ops_d))
        force_fn = jax.jit(lambda u, vel: solver.rhs_forcing(u, vel, cfg))
        add_fn = jax.jit(lambda a, b: a + b)

        def separate_fn(u, cs):
            rho, vel, p, e_spec, q_prim = prim_fn(u)
            grad_prim, nu_t = grad_fn(q_prim, cs)
            rhs = div_fn(u, (rho, vel, p, e_spec), grad_prim, nu_t)
            return add_fn(rhs, force_fn(u, vel))

        # every jitted program in each column, pinned at one compile across
        # warmup + timed iterations (the separate column has five)
        stage_jits = {"prim": prim_fn, "grad": grad_fn, "div": div_fn,
                      "force": force_fn, "add": add_fn}
        watched = {"fused": {"fused": fused_fn},
                   "separate": stage_jits,
                   "pure_jnp": {"pure_jnp": pure_fn}}
        timings = {}
        for impl, fn in (("fused", fused_fn), ("separate", separate_fn),
                         ("pure_jnp", pure_fn)):
            t, counts = trace_audit.certify(
                watched[impl], {k: 1 for k in watched[impl]},
                lambda: common.timeit(fn, u, cs, warmup=5, iters=20))
            compile_counts.update(
                {f"{name}_{impl}_{k}": v for k, v in counts.items()})
            timings[impl] = t
            note = ("interpret-mode (oracle check, not perf)"
                    if impl != "pure_jnp" and backend != "tpu" else "")
            common.row("perf_rhs", backend, name, impl, f"{t:.6f}", note)
            results.append({"backend": backend, "case": name, "impl": impl,
                            "median_s": t})
        speedups[name] = timings["separate"] / timings["fused"]
        common.row("perf_rhs", backend, name, "fused_vs_separate",
                   f"{speedups[name]:.2f}x", "")
    common.save_json("perf_rhs.json",
                     {"default_impl": default_impl(), "rows": results,
                      "fused_vs_separate_speedup": speedups,
                      "certified_compile_counts": compile_counts})
    return {"n_rhs_rows": len(results)}


def run(quick: bool = True) -> dict:
    common.row("# perf_compare", "arch", "shape", "variant",
               "collective_s", "compute_s", "memory_s", "frac", "note")
    n = 0
    for arch, shape, tag, note in COMPARISONS:
        base, opt = _load(arch, shape), _load(arch, shape, tag)
        if not base or not opt or base["status"] != "ok" \
                or opt["status"] != "ok":
            continue
        for label, rec in (("baseline", base), (tag, opt)):
            t = rec["roofline"]
            common.row("perf", arch, shape, label,
                       f"{t['collective_s']:.4f}", f"{t['compute_s']:.4f}",
                       f"{t['memory_s']:.4f}",
                       f"{t['roofline_fraction']:.3f}",
                       note if label != "baseline" else "")
        n += 1
    if n == 0:
        print("no tagged perf artifacts found; run the §Perf commands in "
              "EXPERIMENTS.md first")
    out = {"n_comparisons": n}
    out.update(run_rhs(quick=quick))
    out.update(run_wall_model(quick=quick))
    return out


if __name__ == "__main__":
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--sections", default="",
                        help="comma-separated subset to run "
                             "(rhs,wall_model); default: everything")
    parser.add_argument("--full", action="store_true",
                        help="full shape sweep instead of quick smoke sizes")
    cli = parser.parse_args()
    quick = not cli.full
    sections = [s for s in cli.sections.split(",") if s]
    if not sections:
        run(quick=quick)
    else:
        for section in sections:
            fn = {"rhs": run_rhs, "wall_model": run_wall_model}.get(section)
            if fn is None:
                parser.error(f"unknown section {section!r}")
            fn(quick=quick)

"""Fleet-scaling benchmark: broker throughput, pipeline overlap, the
single fleet program vs per-scenario dispatch, and multi-host scaling.

    PYTHONPATH=src python -m benchmarks.fleet_scaling
    PYTHONPATH=src python -m benchmarks.fleet_scaling \
        --sections single_program,scaling

Four measurements on the mixed reduced fleet (hit_les + channel_wm +
burgers — the heterogeneous benchmark cell):

  * broker throughput — sustained donated-push rate into a per-scenario
    trajectory ring (items/s and MB/s): the device-resident analog of the
    paper's KeyDB PUT path, whose Sec. 3.3 transfer overhead this
    subsystem removes;
  * pipeline overlap — wall time per iteration of the double-buffered
    pipelined FleetRunner (per-scenario DISPATCH path) against the
    SYNCHRONOUS sum of its own rollout and update phases, on identical
    jitted programs.  The headline check: pipelined wall time must sit
    strictly below t_sample + t_update (`overlap_ok` in the artifact);
  * single program vs dispatch — the SAME pipelined iteration as ONE
    compiled super-batch program (`fleet/superbatch.py`, the PR-8 default)
    against the per-scenario dispatch fallback, equal-cost fleet so the
    super-batch carries zero padding.  Artifact key:
    `single_program_vs_dispatch_speedup` (>= 1.0 is the acceptance bar);
  * scaling — strong (fixed fleet, growing `data` axis) and weak (fixed
    envs per device) rows over forced host-platform device counts, each
    measured in a fresh subprocess (XLA_FLAGS must be set before jax
    initializes), plus 2-process `jax.distributed` rows timing each
    host's local shard of the collective-free rollout region
    (phase "rollout_shard" — the CPU runtime cannot execute cross-process
    programs, see launch/mesh.py).

Every timed loop is compile-certified under the trace auditor
(`trace_audit.watch`): the published JSON carries the certified compile
counts, and any retrace inside a timed region fails the run.

Artifact: benchmarks/artifacts/perf_fleet.json.
"""
from __future__ import annotations

import json
import os
import shutil
import socket
import subprocess
import sys
import time

from . import common

FLEET = ("hit_les_reduced", "channel_wm_reduced", "burgers_reduced")


def run_broker(quick: bool = True) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.fleet import broker

    common.row("# perf_fleet_broker", "capacity", "item_mb", "pushes_per_s",
               "mb_per_s")
    # a representative trajectory-shaped item: (T, B, E, n, n, n, C) obs +
    # the scalar lanes, matching the reduced HIT fleet's rollout output
    T, B, E, n = (3, 8, 8, 4) if quick else (10, 64, 8, 4)
    item = {
        "obs": jnp.zeros((T, B, E, n, n, n, 3), jnp.float32),
        "actions": jnp.zeros((T, B, E), jnp.float32),
        "rewards": jnp.zeros((T, B), jnp.float32),
    }
    item_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(item))
    results = []
    from repro.analysis import trace_audit

    for cap in (2, 8):
        ring = broker.ring_init(item, cap)
        ring = broker.push_donated(ring, item)  # compile + warm
        n_push = 50 if quick else 500
        jax.block_until_ready(ring)
        # retrace-certified timed region: every push after the warmup hits
        # the same compiled program (zero cache growth)
        with trace_audit.watch({"push_donated": broker.push_donated}) as w:
            t0 = time.perf_counter()
            for _ in range(n_push):
                ring = broker.push_donated(ring, item)
            jax.block_until_ready(ring)
            dt = time.perf_counter() - t0
        bad = w.check({"push_donated": 0})
        if bad:
            raise RuntimeError(bad[0].message)
        rate = n_push / dt
        mbps = rate * item_bytes / 1e6
        common.row("perf_fleet_broker", cap, round(item_bytes / 1e6, 3),
                   round(rate, 1), round(mbps, 1))
        results.append({"capacity": cap, "item_bytes": item_bytes,
                        "pushes_per_s": rate, "mb_per_s": mbps,
                        "certified_compile_counts": dict(w.growth)})
    return {"items": results}


def _fresh_runner(pipelined: bool, tmpdir: str, n_envs: int, *,
                  single_program: bool = False, costs=None, mesh=None):
    from repro import fleet
    from repro.fleet.pipeline import FleetRunnerConfig

    shutil.rmtree(tmpdir, ignore_errors=True)
    return fleet.make_fleet_runner(
        FLEET, total_envs=n_envs, costs=costs, mesh=mesh,
        run_cfg=FleetRunnerConfig(
            n_iterations=10_000, eval_every=10_000, checkpoint_every=10_000,
            checkpoint_dir=tmpdir, async_checkpoint=False,
            pipelined=pipelined, single_program=single_program))


def run_pipeline(quick: bool = True) -> dict:
    import jax

    n_envs = 6 if quick else 24
    n_iters = 6 if quick else 20
    base = common.ARTIFACTS + "/fleet_bench"

    # synchronous baseline: per-phase times with host sync between phases
    sync = _fresh_runner(False, base + "_sync", n_envs)
    sync.train(1, resume=False)  # compile + warm every program
    records = []
    for k in range(1, 1 + n_iters):
        records.append(sync.run_iteration_sync(k))
    t_sample = sum(r["t_sample_s"] for r in records) / n_iters
    t_update = sum(r["t_update_s"] for r in records) / n_iters

    # pipelined: same programs, dispatch-only loop, one sync at the end on
    # the last UPDATE (params) — the iteration-(N+1) rollout stays in
    # flight, exactly as it does in steady state
    from repro.analysis import trace_audit
    from repro.core.orchestrator import Orchestrator

    pipe = _fresh_runner(True, base + "_pipe", n_envs)
    pipe.train(1, resume=False)  # compile + warm (incl. prologue)
    # certified: the timed loop dispatches only warm programs — any compile
    # here (rollout OR update) would poison the overlap measurement
    with trace_audit.watch({"sample_fleet": Orchestrator.sample_fleet,
                            "fleet_update": pipe._update}) as w:
        t0 = time.perf_counter()
        for k in range(1, 1 + n_iters):
            pipe.run_iteration_pipelined(k)
        jax.block_until_ready(pipe.params)
        t_pipe = (time.perf_counter() - t0) / n_iters
    bad = w.check({"sample_fleet": 0, "fleet_update": 0})
    if bad:
        raise RuntimeError("; ".join(f.message for f in bad))

    sync_sum = t_sample + t_update
    overlap = 1.0 - t_pipe / sync_sum if sync_sum > 0 else 0.0
    common.row("# perf_fleet_pipeline", "n_envs", "iters", "t_sample_s",
               "t_update_s", "sync_sum_s", "t_pipelined_s",
               "overlap_fraction", "ok")
    common.row("perf_fleet_pipeline", n_envs, n_iters, round(t_sample, 4),
               round(t_update, 4), round(sync_sum, 4), round(t_pipe, 4),
               round(overlap, 3), t_pipe < sync_sum)
    return {
        "n_envs": n_envs,
        "n_iterations": n_iters,
        "scenarios": list(FLEET),
        "t_sample_s": t_sample,
        "t_update_s": t_update,
        "sync_sum_s": sync_sum,
        "t_pipelined_s": t_pipe,
        "overlap_fraction": overlap,
        "overlap_ok": bool(t_pipe < sync_sum),
        "certified_compile_counts": dict(w.growth),
    }


def run_single_program(quick: bool = True) -> dict:
    """ONE compiled super-batch program vs per-scenario dispatch, same
    pipelined iteration semantics, equal-cost fleet (zero padding)."""
    import jax

    from repro.analysis import trace_audit
    from repro.core.orchestrator import Orchestrator

    n_envs = 6 if quick else 24
    n_iters = 6 if quick else 20
    base = common.ARTIFACTS + "/fleet_bench"
    costs = {name: 1.0 for name in FLEET}   # equal split -> zero padding

    n_passes = 3   # best-of passes: host jitter dwarfs a 6-iter loop

    def timed_passes(runner, k0: int) -> tuple[float, int]:
        best, k = float("inf"), k0
        for _ in range(n_passes):
            t0 = time.perf_counter()
            for _ in range(n_iters):
                runner.run_iteration_pipelined(k)
                k += 1
            jax.block_until_ready(runner.params)
            best = min(best, (time.perf_counter() - t0) / n_iters)
        return best, k

    dispatch = _fresh_runner(True, base + "_dispatch", n_envs, costs=costs)
    dispatch.train(1, resume=False)         # compile + warm every program
    with trace_audit.watch({"sample_fleet": Orchestrator.sample_fleet,
                            "fleet_update": dispatch._update}) as wd:
        t_dispatch, _ = timed_passes(dispatch, 1)
    bad = wd.check({"sample_fleet": 0, "fleet_update": 0})
    if bad:
        raise RuntimeError("; ".join(f.message for f in bad))

    prog_runner = _fresh_runner(True, base + "_prog", n_envs,
                                single_program=True, costs=costs)
    prog_runner.train(1, resume=False)
    prog = prog_runner.program
    padding = {n: prog.b_pad[n] - prog.n_envs[n] for n in prog.names}
    with trace_audit.watch({"fleet_program_step": prog._step}) as wp:
        t_program, _ = timed_passes(prog_runner, 1)
    bad = wp.check({"fleet_program_step": 0})
    if bad:
        raise RuntimeError("; ".join(f.message for f in bad))

    speedup = t_dispatch / t_program if t_program > 0 else 0.0
    common.row("# perf_fleet_single_program", "n_envs", "iters",
               "t_dispatch_s", "t_program_s", "speedup", "ok")
    common.row("perf_fleet_single_program", n_envs, n_iters,
               round(t_dispatch, 4), round(t_program, 4), round(speedup, 3),
               speedup >= 1.0)
    return {
        "n_envs": n_envs,
        "n_iterations": n_iters,
        "scenarios": list(FLEET),
        "padding_rows": padding,
        "t_dispatch_s": t_dispatch,
        "t_program_s": t_program,
        "single_program_vs_dispatch_speedup": speedup,
        "speedup_ok": bool(speedup >= 1.0),
        "certified_compile_counts": {**wd.growth, **wp.growth},
    }


# Worker for the per-device-count rows: XLA_FLAGS must force the host
# device count BEFORE jax initializes, hence a fresh subprocess per row.
_SCALING_WORKER = r"""
import json, os, sys, time
spec = json.loads(sys.argv[1])
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           f" --xla_force_host_platform_device_count={spec['n_devices']}")
import jax
from repro.analysis import trace_audit
from repro.launch import mesh as mesh_lib
from benchmarks.fleet_scaling import FLEET, _fresh_runner

mesh = mesh_lib.make_fleet_mesh()
runner = _fresh_runner(True, spec["tmpdir"], spec["n_envs"],
                       single_program=True, mesh=mesh,
                       costs={n: 1.0 for n in FLEET})
prog = runner.program
runner.train(1, resume=False)   # compile + warm
# one more warm step: with a real mesh the first step's outputs pick up
# explicit shardings, so the program reaches its steady-state compiled
# form on the SECOND call — only then is the zero-retrace pin fair
runner.run_iteration_pipelined(1)
jax.block_until_ready(runner.params)
with trace_audit.watch({"fleet_program_step": prog._step}) as w:
    t0 = time.perf_counter()
    for k in range(2, 2 + spec["n_iters"]):
        runner.run_iteration_pipelined(k)
    jax.block_until_ready(runner.params)
    t_step = (time.perf_counter() - t0) / spec["n_iters"]
bad = w.check({"fleet_program_step": 0})
if bad:
    raise RuntimeError("; ".join(f.message for f in bad))
print("RESULT " + json.dumps({
    "n_devices": spec["n_devices"], "n_envs": spec["n_envs"],
    "n_data": prog.n_data, "t_step_s": t_step,
    "certified_compile_counts": dict(w.growth)}), flush=True)
"""

# Worker for the 2-process distributed rows: each process times its LOCAL
# shard of the collective-free rollout region (the CPU runtime cannot run
# cross-process programs — launch/mesh.py module docstring).
_DISTRIBUTED_WORKER = r"""
import json, os, sys, time
spec = json.loads(sys.argv[1])
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=2")
os.environ["JAX_COORDINATOR_ADDRESS"] = spec["coordinator"]
os.environ["JAX_NUM_PROCESSES"] = str(spec["num_processes"])
os.environ["JAX_PROCESS_ID"] = str(spec["process_id"])
import jax
from repro.analysis import trace_audit
from repro.launch import mesh as mesh_lib
from benchmarks.fleet_scaling import FLEET, _fresh_runner

assert mesh_lib.init_distributed()
assert jax.process_count() == spec["num_processes"]
fleet_mesh = mesh_lib.make_fleet_mesh()   # spans every process
runner = _fresh_runner(True, spec["tmpdir"], spec["n_envs"],
                       costs={n: 1.0 for n in FLEET})
from repro.fleet import superbatch as sb_lib
prog = sb_lib.FleetProgram(runner.forch, runner.weights, runner.ppo_cfg,
                           mesh=mesh_lib.make_local_mesh())
roll = jax.jit(prog.rollout_super_batch)
keys = runner._keys(0)
jax.block_until_ready(roll(runner.params, keys))   # compile + warm
with trace_audit.watch({"rollout_shard": roll}) as w:
    t0 = time.perf_counter()
    for _ in range(spec["n_iters"]):
        jax.block_until_ready(roll(runner.params, keys))
    t_roll = (time.perf_counter() - t0) / spec["n_iters"]
bad = w.check({"rollout_shard": 0})
if bad:
    raise RuntimeError("; ".join(f.message for f in bad))
print("RESULT " + json.dumps({
    "phase": "rollout_shard", "process_id": spec["process_id"],
    "num_processes": spec["num_processes"],
    "global_devices": len(jax.devices()),
    "local_data_shards": prog.n_data, "n_envs": spec["n_envs"],
    "t_rollout_s": t_roll,
    "certified_compile_counts": dict(w.growth)}), flush=True)
"""


def _worker_env() -> dict:
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    return env


def _run_worker(script: str, spec: dict, env: dict) -> dict:
    out = subprocess.run([sys.executable, "-c", script, json.dumps(spec)],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"scaling worker failed:\n{out.stdout}\n"
                           f"{out.stderr}")
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"scaling worker produced no RESULT line:\n"
                       f"{out.stdout}")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_scaling(quick: bool = True) -> dict:
    """Strong/weak per-host rows over forced device counts + 2-process
    distributed rows (each host's local rollout_shard)."""
    env = _worker_env()
    base = common.ARTIFACTS + "/fleet_bench_scaling"
    device_counts = (1, 2) if quick else (1, 2, 4)
    n_iters = 3 if quick else 10
    strong_envs = 6 if quick else 24        # fixed fleet, growing data axis
    per_device = 3 if quick else 12         # weak: fixed envs per device

    strong, weak = [], []
    common.row("# perf_fleet_scaling", "mode", "n_devices", "n_envs",
               "t_step_s")
    for nd in device_counts:
        rec = _run_worker(_SCALING_WORKER, {
            "n_devices": nd, "n_envs": strong_envs, "n_iters": n_iters,
            "tmpdir": f"{base}_strong_{nd}"}, env)
        strong.append(rec)
        common.row("perf_fleet_scaling", "strong", nd, strong_envs,
                   round(rec["t_step_s"], 4))
    for nd in device_counts:
        rec = _run_worker(_SCALING_WORKER, {
            "n_devices": nd, "n_envs": per_device * nd, "n_iters": n_iters,
            "tmpdir": f"{base}_weak_{nd}"}, env)
        weak.append(rec)
        common.row("perf_fleet_scaling", "weak", nd, per_device * nd,
                   round(rec["t_step_s"], 4))

    # 2-process distributed rows: per-host local rollout_shard times
    coordinator = f"127.0.0.1:{_free_port()}"
    procs = [subprocess.Popen(
        [sys.executable, "-c", _DISTRIBUTED_WORKER, json.dumps({
            "coordinator": coordinator, "num_processes": 2,
            "process_id": pid, "n_envs": strong_envs, "n_iters": n_iters,
            "tmpdir": f"{base}_dist_{pid}"})],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True) for pid in range(2)]
    outs = [p.communicate(timeout=1200)[0] for p in procs]
    distributed = []
    for pid, (p, out) in enumerate(zip(procs, outs)):
        if p.returncode != 0:
            raise RuntimeError(f"distributed worker {pid} failed:\n{out}")
        rec = next(json.loads(line[len("RESULT "):])
                   for line in out.splitlines()
                   if line.startswith("RESULT "))
        distributed.append(rec)
        common.row("perf_fleet_scaling", "distributed", rec["process_id"],
                   rec["n_envs"], round(rec["t_rollout_s"], 4))
    return {"strong": strong, "weak": weak, "distributed": distributed}


SECTIONS = {
    "broker": run_broker,
    "pipeline": run_pipeline,
    "single_program": run_single_program,
    "scaling": run_scaling,
}


def run(quick: bool = True, sections: tuple[str, ...] = ()) -> dict:
    names = sections or tuple(SECTIONS)
    path = os.path.join(common.ARTIFACTS, "perf_fleet.json")
    payload = {}
    if sections and os.path.exists(path):
        with open(path) as f:          # partial runs refresh their section
            payload = json.load(f)
    for name in names:
        payload[name] = SECTIONS[name](quick)
    path = common.save_json("perf_fleet.json", payload)
    print(f"wrote {path}", flush=True)
    if "pipeline" in payload and not payload["pipeline"]["overlap_ok"]:
        print("WARNING: pipelined wall time did not beat the synchronous "
              "phase sum on this host", flush=True)
    if ("single_program" in payload
            and not payload["single_program"]["speedup_ok"]):
        print("WARNING: the single fleet program did not beat per-scenario "
              "dispatch on this host", flush=True)
    return payload


def main(argv=None) -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--sections", default="",
                        help="comma-separated subset of "
                             f"{','.join(SECTIONS)} (default: all)")
    parser.add_argument("--full", action="store_true",
                        help="full (slow) shapes instead of quick ones")
    cli = parser.parse_args(argv)
    names = tuple(s for s in cli.sections.split(",") if s)
    for s in names:
        if s not in SECTIONS:
            parser.error(f"unknown section {s!r}")
    run(quick=not cli.full, sections=names)


if __name__ == "__main__":
    main()

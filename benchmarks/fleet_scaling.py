"""Fleet-scaling benchmark: broker throughput + pipeline overlap.

    PYTHONPATH=src python -m benchmarks.fleet_scaling

Two measurements on the mixed reduced fleet (hit_les + channel_wm +
burgers — the heterogeneous benchmark cell):

  * broker throughput — sustained donated-push rate into a per-scenario
    trajectory ring (items/s and MB/s): the device-resident analog of the
    paper's KeyDB PUT path, whose Sec. 3.3 transfer overhead this
    subsystem removes;
  * pipeline overlap — wall time per iteration of the double-buffered
    pipelined FleetRunner against the SYNCHRONOUS sum of its own rollout
    and update phases, on identical jitted programs.  The headline check:
    pipelined wall time must sit strictly below t_sample + t_update
    (`overlap_ok` in the artifact — the fleet CI acceptance bar).

Artifact: benchmarks/artifacts/perf_fleet.json.
"""
from __future__ import annotations

import shutil
import time

from . import common

FLEET = ("hit_les_reduced", "channel_wm_reduced", "burgers_reduced")


def run_broker(quick: bool = True) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.fleet import broker

    common.row("# perf_fleet_broker", "capacity", "item_mb", "pushes_per_s",
               "mb_per_s")
    # a representative trajectory-shaped item: (T, B, E, n, n, n, C) obs +
    # the scalar lanes, matching the reduced HIT fleet's rollout output
    T, B, E, n = (3, 8, 8, 4) if quick else (10, 64, 8, 4)
    item = {
        "obs": jnp.zeros((T, B, E, n, n, n, 3), jnp.float32),
        "actions": jnp.zeros((T, B, E), jnp.float32),
        "rewards": jnp.zeros((T, B), jnp.float32),
    }
    item_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(item))
    results = []
    from repro.analysis import trace_audit

    for cap in (2, 8):
        ring = broker.ring_init(item, cap)
        ring = broker.push_donated(ring, item)  # compile + warm
        n_push = 50 if quick else 500
        jax.block_until_ready(ring)
        # retrace-certified timed region: every push after the warmup hits
        # the same compiled program (zero cache growth)
        with trace_audit.watch({"push_donated": broker.push_donated}) as w:
            t0 = time.perf_counter()
            for _ in range(n_push):
                ring = broker.push_donated(ring, item)
            jax.block_until_ready(ring)
            dt = time.perf_counter() - t0
        bad = w.check({"push_donated": 0})
        if bad:
            raise RuntimeError(bad[0].message)
        rate = n_push / dt
        mbps = rate * item_bytes / 1e6
        common.row("perf_fleet_broker", cap, round(item_bytes / 1e6, 3),
                   round(rate, 1), round(mbps, 1))
        results.append({"capacity": cap, "item_bytes": item_bytes,
                        "pushes_per_s": rate, "mb_per_s": mbps,
                        "certified_compile_counts": dict(w.growth)})
    return {"items": results}


def _fresh_runner(pipelined: bool, tmpdir: str, n_envs: int):
    from repro import fleet
    from repro.fleet.pipeline import FleetRunnerConfig

    shutil.rmtree(tmpdir, ignore_errors=True)
    return fleet.make_fleet_runner(
        FLEET, total_envs=n_envs,
        run_cfg=FleetRunnerConfig(
            n_iterations=10_000, eval_every=10_000, checkpoint_every=10_000,
            checkpoint_dir=tmpdir, async_checkpoint=False,
            pipelined=pipelined))


def run_pipeline(quick: bool = True) -> dict:
    import jax

    n_envs = 6 if quick else 24
    n_iters = 6 if quick else 20
    base = common.ARTIFACTS + "/fleet_bench"

    # synchronous baseline: per-phase times with host sync between phases
    sync = _fresh_runner(False, base + "_sync", n_envs)
    sync.train(1, resume=False)  # compile + warm every program
    records = []
    for k in range(1, 1 + n_iters):
        records.append(sync.run_iteration_sync(k))
    t_sample = sum(r["t_sample_s"] for r in records) / n_iters
    t_update = sum(r["t_update_s"] for r in records) / n_iters

    # pipelined: same programs, dispatch-only loop, one sync at the end on
    # the last UPDATE (params) — the iteration-(N+1) rollout stays in
    # flight, exactly as it does in steady state
    from repro.analysis import trace_audit
    from repro.core.orchestrator import Orchestrator

    pipe = _fresh_runner(True, base + "_pipe", n_envs)
    pipe.train(1, resume=False)  # compile + warm (incl. prologue)
    # certified: the timed loop dispatches only warm programs — any compile
    # here (rollout OR update) would poison the overlap measurement
    with trace_audit.watch({"sample_fleet": Orchestrator.sample_fleet,
                            "fleet_update": pipe._update}) as w:
        t0 = time.perf_counter()
        for k in range(1, 1 + n_iters):
            pipe.run_iteration_pipelined(k)
        jax.block_until_ready(pipe.params)
        t_pipe = (time.perf_counter() - t0) / n_iters
    bad = w.check({"sample_fleet": 0, "fleet_update": 0})
    if bad:
        raise RuntimeError("; ".join(f.message for f in bad))

    sync_sum = t_sample + t_update
    overlap = 1.0 - t_pipe / sync_sum if sync_sum > 0 else 0.0
    common.row("# perf_fleet_pipeline", "n_envs", "iters", "t_sample_s",
               "t_update_s", "sync_sum_s", "t_pipelined_s",
               "overlap_fraction", "ok")
    common.row("perf_fleet_pipeline", n_envs, n_iters, round(t_sample, 4),
               round(t_update, 4), round(sync_sum, 4), round(t_pipe, 4),
               round(overlap, 3), t_pipe < sync_sum)
    return {
        "n_envs": n_envs,
        "n_iterations": n_iters,
        "scenarios": list(FLEET),
        "t_sample_s": t_sample,
        "t_update_s": t_update,
        "sync_sum_s": sync_sum,
        "t_pipelined_s": t_pipe,
        "overlap_fraction": overlap,
        "overlap_ok": bool(t_pipe < sync_sum),
        "certified_compile_counts": dict(w.growth),
    }


def run(quick: bool = True) -> dict:
    payload = {"broker": run_broker(quick), "pipeline": run_pipeline(quick)}
    path = common.save_json("perf_fleet.json", payload)
    print(f"wrote {path}", flush=True)
    if not payload["pipeline"]["overlap_ok"]:
        print("WARNING: pipelined wall time did not beat the synchronous "
              "phase sum on this host", flush=True)
    return payload


if __name__ == "__main__":
    run()

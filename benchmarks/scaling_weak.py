"""Paper Fig. 3 — weak scaling: parallel environments per training iteration.

The paper measures Speedup(n_envs) = T_sequential(n_envs) / T_parallel(n_envs)
on up to 1024 FLEXI instances / 2048 cores.  Offline we have one CPU device,
so this benchmark reports BOTH:

  (a) measured: wall time of the jitted batched fleet rollout at n_envs =
      1..8 on the reduced HIT config — the CPU analog of the paper's curve
      (vmapped envs share one device, so ideal speedup == n_envs while the
      per-iteration fixed cost — Relexi's "sequential work" — bounds it);
  (b) mesh-derived: on the production mesh the fleet is embarrassingly
      batch-parallel (one env per (pod,data) shard); the loss terms the
      paper attributes to launch/DB/polling collapse into the PPO update's
      gradient all-reduce, whose per-device byte volume is constant in
      n_envs — i.e. the framework weak-scales by construction.  We report
      the measured all-reduce bytes from the dry-run artifact.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import envs
from repro.core import policy as policy_lib, rollout as rollout_lib

from . import common


def run(quick: bool = True) -> dict:
    env = envs.make("hit_les_reduced")
    pcfg = policy_lib.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
    params = policy_lib.init(jax.random.PRNGKey(0), pcfg)
    bank = env.initial_state_bank(jax.random.PRNGKey(1), 9)

    sizes = (1, 2, 4, 8) if quick else (1, 2, 4, 8, 16, 32)
    results = []
    jitted = {}
    common.row("# fig3_weak_scaling", "n_envs", "t_episode_s",
               "t_per_env_s", "speedup_vs_sequential")
    t1 = None
    for n in sizes:
        u0 = jnp.take(bank, jnp.arange(n) % 8, axis=0)
        fn = jax.jit(lambda p, u, k: rollout_lib.rollout(p, pcfg, env, u, k))
        t = common.timeit(fn, params, u0, jax.random.PRNGKey(2),
                          warmup=1, iters=2)
        if t1 is None:
            t1 = t
        speedup = n * t1 / t  # T_seq(n)/T_par(n) with T_seq = n * T(1)
        results.append({"n_envs": n, "t_episode_s": t, "speedup": speedup})
        common.row("fig3", n, f"{t:.3f}", f"{t/n:.3f}", f"{speedup:.2f}")
    common.save_json("fig3_weak_scaling.json", results)
    return {"rows": results}


if __name__ == "__main__":
    run(quick=True)

"""Paper Fig. 5 + Table 1 — RL turbulence-model training and baselines.

The paper trains for 4,000 iterations on 2,048 cores; offline we run the
same loop at smoke scale (reduced HIT config) for a few dozen iterations and
verify the paper's three claims at that scale:

  1. the collected return IMPROVES over training (Fig. 5 top-left),
  2. more parallel episodes -> smoother/faster improvement (16 vs 64 envs),
  3. the trained dynamic-C_s agent beats the static baselines the paper
     compares against — Smagorinsky (C_s = 0.17) and implicit LES
     (C_s = 0) — in the spectral-error reward metric (Fig. 5 bottom).

Baselines are one-line configs of the same solver, exactly as in the paper.
"""
from __future__ import annotations

from repro import envs
from repro.core.orchestrator import FleetConfig, Orchestrator
from repro.core.ppo import PPOConfig
from repro.core.rollout import constant_action_return
from repro.core.runner import Runner, RunnerConfig

from . import common


def constant_cs_return(orch: Orchestrator, cs_value: float) -> float:
    """Episode return of a constant-C_s policy on the held-out test state."""
    return constant_action_return(orch.env, orch.test_state(), cs_value)


def _run_channel_family(env_name: str, tag: str, quick: bool,
                        iterations: int | None) -> dict:
    """Shared channel-scenario harness: training curve + the two static
    wall-model baselines, tagged and saved under `tag`.

    The static baselines are the channel analogs of the paper's Fig. 5
    bottom: the equilibrium wall model applied as-is (a = 1) and no wall
    stress at all (a = 0) — the trained per-element scaling should at least
    match the equilibrium model on the profile-error reward.
    """
    env = envs.make(f"{env_name}_reduced" if quick else env_name)
    iters = iterations or (12 if quick else 60)
    results = {"env": env_name,
               "obs_channels": list(env.obs_spec.channel_names)}
    common.row(f"# {tag}_training", "n_envs", "iteration", "return_norm")
    runner = Runner(
        env, FleetConfig(n_envs=2, bank_size=9),
        ppo_cfg=PPOConfig(),
        run_cfg=RunnerConfig(n_iterations=iters, eval_every=10**9,
                             checkpoint_every=10**9,
                             checkpoint_dir=f"/tmp/bench_{tag}",
                             async_checkpoint=False),
    )
    history = runner.train(resume=False)
    curve = [r["return_norm"] for r in history if "return_norm" in r]
    for i, r in enumerate(curve):
        if i % max(1, len(curve) // 6) == 0 or i == len(curve) - 1:
            common.row(tag, 2, i, f"{r:.4f}")
    results["curve_2_envs"] = curve
    results["trained_eval"] = float(runner.orch.evaluate(runner.params))
    equil = constant_cs_return(runner.orch, 1.0)
    no_model = constant_cs_return(runner.orch, 0.0)
    results["baseline_equilibrium_wm_a1"] = equil
    results["baseline_no_wall_stress_a0"] = no_model
    common.row(f"{tag}_baselines", "equilibrium_wm", f"{equil:.4f}")
    common.row(f"{tag}_baselines", "no_wall_stress", f"{no_model:.4f}")
    common.row(f"{tag}_baselines", "rl_trained", f"{results['trained_eval']:.4f}")
    common.save_json(f"{tag}_training.json", results)
    return results


def run_channel(quick: bool = True, iterations: int | None = None) -> dict:
    """Training curve + static baselines, 3-channel `channel_wm`."""
    return _run_channel_family("channel_wm", "channel", quick, iterations)


def run_channel_p(quick: bool = True, iterations: int | None = None) -> dict:
    """Training curve + static baselines for `channel_wm_p` — the
    4-channel (velocity + near-wall pressure) variant, so its curve lands
    next to the HIT and 3-channel channel ones."""
    return _run_channel_family("channel_wm_p", "channel_p", quick, iterations)


def run(quick: bool = True, iterations: int | None = None) -> dict:
    env = envs.make("hit_les_reduced")
    iters = iterations or (12 if quick else 60)
    results = {}
    common.row("# fig5_training", "n_envs", "iteration", "return_norm")

    for n_envs in ((2,) if quick else (2, 8)):
        runner = Runner(
            env, FleetConfig(n_envs=n_envs, bank_size=max(9, n_envs + 1)),
            ppo_cfg=PPOConfig(),
            run_cfg=RunnerConfig(n_iterations=iters, eval_every=10**9,
                                 checkpoint_every=10**9,
                                 checkpoint_dir="/tmp/bench_relexi",
                                 async_checkpoint=False),
        )
        history = runner.train(resume=False)
        curve = [r["return_norm"] for r in history if "return_norm" in r]
        for i, r in enumerate(curve):
            if i % max(1, len(curve) // 6) == 0 or i == len(curve) - 1:
                common.row("fig5", n_envs, i, f"{r:.4f}")
        results[f"curve_{n_envs}_envs"] = curve
        results[f"trained_eval_{n_envs}"] = float(runner.orch.evaluate(
            runner.params))
        last_orch = runner.orch
        trained_first, trained_last = curve[0], curve[-1]
        common.row("fig5_improved", n_envs, f"{trained_first:.4f}",
                   f"{trained_last:.4f}")

    # paper baselines (Fig. 5 bottom-left): static Smagorinsky and implicit
    smag = constant_cs_return(last_orch, 0.17)
    implicit = constant_cs_return(last_orch, 0.0)
    results["baseline_smagorinsky_cs0.17"] = smag
    results["baseline_implicit_cs0"] = implicit
    common.row("fig5_baselines", "smagorinsky", f"{smag:.4f}")
    common.row("fig5_baselines", "implicit", f"{implicit:.4f}")
    common.row("fig5_baselines", "rl_trained",
               f"{results[f'trained_eval_{n_envs}']:.4f}")
    common.save_json("fig5_training.json", results)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--env", default="hit",
                    choices=("hit", "channel_wm", "channel_wm_p"),
                    help="which scenario's training curve to produce")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.env == "channel_wm":
        run_channel(quick=not args.full)
    elif args.env == "channel_wm_p":
        run_channel_p(quick=not args.full)
    else:
        run(quick=not args.full)

"""Paper Sec. 3.3 — environment-launch overhead.

The paper found that repeatedly STARTING hundreds of MPI jobs could cost
more than the simulation itself, and fixed it with MPMD batch launches and
RAM-disk staging.  In the TPU-native design the entire fleet is ONE jitted
program, so the analogous costs are:

  * one-time: XLA compile of the fleet program (amortized over training,
    the analog of the MPMD batch launch),
  * per-iteration: dispatch + initial-state indexing from the device bank
    (the analog of staging restart files from the RAM disk).

This benchmark measures both vs fleet size and reports the per-env overhead
the paper's Sec. 3.3 worries about — it is microseconds here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import envs
from repro.core import policy as policy_lib, rollout as rollout_lib

from . import common


def run(quick: bool = True) -> dict:
    env = envs.make("hit_les_reduced")
    pcfg = policy_lib.PolicyConfig.from_specs(env.obs_spec, env.action_spec)
    params = policy_lib.init(jax.random.PRNGKey(0), pcfg)
    bank = env.initial_state_bank(jax.random.PRNGKey(1), 9)

    rows = []
    common.row("# sec3.3_launch_overhead", "n_envs", "compile_s",
               "staging_us_per_env", "dispatch_us")
    for n in (1, 4) if quick else (1, 4, 16):
        u0 = jnp.take(bank, jnp.arange(n) % 8, axis=0)

        def step_once(p, u, k):
            return rollout_lib.rollout(p, pcfg, env, u, k)

        fn = jax.jit(step_once)
        t0 = time.perf_counter()
        fn.lower(params, u0, jax.random.PRNGKey(0)).compile()
        compile_s = time.perf_counter() - t0

        stage = jax.jit(lambda k: jnp.take(bank, jax.random.randint(
            k, (n,), 0, 8), axis=0))
        t_stage = common.timeit(stage, jax.random.PRNGKey(3), warmup=1,
                                iters=3)
        # dispatch-only cost: trivial jitted fn of the same arity
        f_disp = jax.jit(lambda p, u, k: u)
        t_disp = common.timeit(f_disp, params, u0, jax.random.PRNGKey(0),
                               warmup=1, iters=5)
        rows.append({"n_envs": n, "compile_s": compile_s,
                     "staging_s": t_stage, "dispatch_s": t_disp})
        common.row("sec3.3", n, f"{compile_s:.2f}",
                   f"{t_stage/n*1e6:.1f}", f"{t_disp*1e6:.1f}")
    common.save_json("launch_overhead.json", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run(quick=True)

"""Serving latency/throughput benchmark: p50/p99 vs batch-bucket size.

    PYTHONPATH=src python -m benchmarks.perf_serve
    PYTHONPATH=src python -m benchmarks.perf_serve --full
    PYTHONPATH=src python -m benchmarks.perf_serve --sections latency

Measures the full request path of `repro.serve.ControllerService` — host
batching + padding, the jitted (scenario, bucket) `serve_step` dispatch,
and the host readback a caller blocks on — per scenario and per bucket:

  * latency    — per-flush wall times at a fixed bucket occupancy; the
    published rows carry p50/p99/mean latency and sustained requests/s.
    Each timed region is compile-certified under the trace auditor at
    EXACTLY 1 compile (the bucket's first-touch trace; the timed calls
    after it must all hit the warm program — a retrace poisons tail
    latency and fails the run);
  * padding    — occupancy sweep inside one bucket (n_valid = 1..bucket):
    the cost of a padding row vs a real row.  Every occupancy shares the
    bucket's single compiled program, so the whole sweep is certified at
    exactly 1 compile — padding never triggers a retrace.

Artifact: benchmarks/artifacts/perf_serve.json.
"""
from __future__ import annotations

import os
import time

from . import common

SCENARIOS = ("hit_les_reduced", "burgers_reduced")


def _service(buckets):
    import jax

    from repro import envs
    from repro.fleet import multitask
    from repro.serve import ControllerService

    mcfg = multitask.MultiTaskConfig.from_envs(
        [(n, envs.make(n)) for n in SCENARIOS])
    params = multitask.init(jax.random.PRNGKey(0), mcfg)
    svc = ControllerService(params, mcfg,
                            buckets=buckets, max_slots=4 * buckets[-1])
    return svc, mcfg


def _obs_rows(mcfg, name: str, n: int):
    import jax
    import numpy as np

    head = mcfg.head(name)
    shape = (n, head.n_elements, *head.spatial, head.channels)
    return np.asarray(jax.random.normal(jax.random.PRNGKey(1), shape,
                                        "float32"))


def _percentile(sorted_times: list[float], q: float) -> float:
    idx = min(len(sorted_times) - 1, int(round(q * (len(sorted_times) - 1))))
    return sorted_times[idx]


def run_latency(quick: bool = True) -> dict:
    import jax

    from repro.analysis import trace_audit

    buckets = (1, 2, 4, 8) if quick else (1, 2, 4, 8, 16, 32)
    n_iters = 30 if quick else 200
    svc, mcfg = _service(buckets)
    common.row("# perf_serve_latency", "scenario", "bucket", "iters",
               "p50_ms", "p99_ms", "mean_ms", "req_per_s")
    rows = []
    for name in SCENARIOS:
        for bucket in buckets:
            rows_np = _obs_rows(mcfg, name, bucket)

            def flush_once():
                for r in rows_np:
                    svc.submit(name, r)
                return svc.flush()

            def body():
                flush_once()          # first-touch: the bucket's one compile
                flush_once()          # warm
                times = []
                for _ in range(n_iters):
                    t0 = time.perf_counter()
                    flush_once()      # includes the host readback callers
                    times.append(time.perf_counter() - t0)   # block on
                return times

            region = f"serve_{name}_b{bucket}"
            times, counts = trace_audit.certify(
                {region: svc._step}, {region: 1}, body)
            times.sort()
            p50, p99 = _percentile(times, 0.50), _percentile(times, 0.99)
            mean = sum(times) / len(times)
            rps = bucket / mean
            common.row("perf_serve_latency", name, bucket, n_iters,
                       round(p50 * 1e3, 3), round(p99 * 1e3, 3),
                       round(mean * 1e3, 3), round(rps, 1))
            rows.append({
                "scenario": name, "bucket": bucket, "n_iters": n_iters,
                "p50_latency_ms": p50 * 1e3, "p99_latency_ms": p99 * 1e3,
                "mean_latency_ms": mean * 1e3, "requests_per_s": rps,
                "certified_compile_counts": counts})
    # sanity: the telemetry counters saw every request the timer sent
    stats = svc.stats()
    expected = {name: sum(b * (n_iters + 2) for b in buckets)
                for name in SCENARIOS}
    for name in SCENARIOS:
        if stats[name]["requests"] != expected[name]:
            raise RuntimeError(
                f"telemetry mismatch for {name}: served "
                f"{stats[name]['requests']}, expected {expected[name]}")
    return {"backend": jax.default_backend(), "buckets": list(buckets),
            "scenarios": list(SCENARIOS), "rows": rows,
            "telemetry": stats}


def run_padding(quick: bool = True) -> dict:
    """Padding-row overhead: one bucket, occupancy swept 1..bucket — all
    occupancies share the single compiled program (padding is free at
    compile granularity; the sweep certifies exactly 1 compile total)."""
    from repro.analysis import trace_audit

    # deliberately NOT a power of two from the latency ladder: jit traces
    # are cached globally per (fn, shapes, statics), so reusing a latency
    # bucket here would read as 0 compiles and fail the certification
    bucket = 6 if quick else 24
    n_iters = 20 if quick else 100
    svc, mcfg = _service((bucket,))
    name = SCENARIOS[0]
    rows_np = _obs_rows(mcfg, name, bucket)

    def body():
        out = []
        for n_valid in range(1, bucket + 1):
            for r in rows_np[:n_valid]:
                svc.submit(name, r)
            svc.flush()               # occupancy's first (and only) trace
            times = []
            for _ in range(n_iters):
                t0 = time.perf_counter()
                for r in rows_np[:n_valid]:
                    svc.submit(name, r)
                svc.flush()
                times.append(time.perf_counter() - t0)
            times.sort()
            out.append({"n_valid": n_valid, "bucket": bucket,
                        "p50_latency_ms": _percentile(times, 0.50) * 1e3,
                        "p99_latency_ms": _percentile(times, 0.99) * 1e3})
        return out

    region = f"serve_padding_b{bucket}"
    occupancy, counts = trace_audit.certify(
        {region: svc._step}, {region: 1}, body)
    common.row("# perf_serve_padding", "bucket", "n_valid", "p50_ms",
               "p99_ms")
    for rec in occupancy:
        common.row("perf_serve_padding", bucket, rec["n_valid"],
                   round(rec["p50_latency_ms"], 3),
                   round(rec["p99_latency_ms"], 3))
    return {"scenario": name, "bucket": bucket, "rows": occupancy,
            "certified_compile_counts": counts}


SECTIONS = {
    "latency": run_latency,
    "padding": run_padding,
}


def run(quick: bool = True, sections: tuple[str, ...] = ()) -> dict:
    import json

    names = sections or tuple(SECTIONS)
    path = os.path.join(common.ARTIFACTS, "perf_serve.json")
    payload = {}
    if sections and os.path.exists(path):
        with open(path) as f:          # partial runs refresh their section
            payload = json.load(f)
    for name in names:
        payload[name] = SECTIONS[name](quick)
    path = common.save_json("perf_serve.json", payload)
    print(f"wrote {path}", flush=True)
    return payload


def main(argv=None) -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--sections", default="",
                        help="comma-separated subset of "
                             f"{','.join(SECTIONS)} (default: all)")
    parser.add_argument("--full", action="store_true",
                        help="full (slow) shapes instead of quick ones")
    cli = parser.parse_args(argv)
    names = tuple(s for s in cli.sections.split(",") if s)
    for s in names:
        if s not in SECTIONS:
            parser.error(f"unknown section {s!r}")
    run(quick=not cli.full, sections=names)


if __name__ == "__main__":
    main()

"""Roofline table: reads the dry-run artifacts (launch/dryrun.py) and prints
the per-(arch x shape) compute/memory/collective terms — the §Roofline
source of EXPERIMENTS.md.  Run the dry-run first:

    python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

from . import common

DRYRUN_DIR = os.path.join(common.ARTIFACTS, "dryrun")


def load(mesh: str = "single", tag: str = "") -> list[dict]:
    from repro.configs.shapes import SHAPES
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"{mesh}_*.json"))):
        base = os.path.basename(path)
        untagged = any(base.endswith(f"_{s}.json") for s in SHAPES)
        if tag and not base.endswith(f"_{tag}.json"):
            continue
        if not tag and not untagged:
            continue
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def print_table(mesh: str = "single", tag: str = "") -> list[dict]:
    rows = load(mesh, tag)
    common.row("# roofline", "arch", "shape", "status", "bound",
               "compute_s", "memory_s", "memory_raw_s", "collective_s",
               "roofline_frac", "useful_flop_ratio")
    for r in rows:
        if r["status"] != "ok":
            common.row("roofline", r["arch"], r["shape"], r["status"],
                       r.get("reason", r.get("error", ""))[:60], "", "", "",
                       "", "", "")
            continue
        t = r["roofline"]
        ratio = r.get("useful_flop_ratio")
        common.row("roofline", r["arch"], r["shape"], "ok", t["bound"],
                   f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
                   f"{t.get('memory_raw_s', t['memory_s']):.4f}",
                   f"{t['collective_s']:.4f}",
                   f"{t['roofline_fraction']:.3f}",
                   f"{ratio:.2f}" if ratio else "")
    return rows


def rhs_kernel_entry(quick: bool = True) -> dict:
    """Arithmetic-intensity entry for the fused DGSEM-RHS mega-kernel.

    Compiles the pure-jnp reference RHS and reads XLA's own cost analysis
    (flops, bytes accessed) through the `cost_analysis_dict` shim, then
    contrasts the unfused arithmetic intensity with the fused ideal — the
    mega-kernel touches HBM only for the state in, cs field in and RHS out
    (every intermediate lives in VMEM), so its AI is flops over that
    minimal traffic.  Writes roofline_rhs.json.
    """
    import jax
    import jax.numpy as jnp

    from repro.cfd import initial, solver
    from repro.cfd.solver import HITConfig
    from repro.launch.hlo_analysis import cost_analysis_dict

    cases = [("hit_reduced", HITConfig(n_poly=3, n_elem=2,
                                       use_kernels=False))]
    if not quick:
        cases.append(("hit_24dof", HITConfig(n_poly=5, n_elem=4,
                                             use_kernels=False)))
    common.row("# roofline_rhs", "case", "flops", "bytes_unfused",
               "bytes_fused_ideal", "ai_unfused", "ai_fused")
    entries = []
    for name, cfg in cases:
        ops_d = cfg.operators()
        u = initial.sample_initial_state(jax.random.PRNGKey(0), cfg)
        cs = jnp.full(u.shape[:-1], 0.17, u.dtype)
        compiled = jax.jit(
            lambda u, cs: solver.navier_stokes_rhs(u, cs, cfg, ops_d)
        ).lower(u, cs).compile()
        cost = cost_analysis_dict(compiled)
        flops = float(cost.get("flops", 0.0))
        bytes_unfused = float(cost.get("bytes accessed", 0.0))
        # fused ideal: read state + cs, write rhs — intermediates in VMEM
        bytes_fused = float((2 * u.size + cs.size) * u.dtype.itemsize)
        entry = {
            "case": name,
            "flops": flops,
            "bytes_unfused": bytes_unfused,
            "bytes_fused_ideal": bytes_fused,
            "ai_unfused": flops / bytes_unfused if bytes_unfused else None,
            "ai_fused": flops / bytes_fused if bytes_fused else None,
        }
        entries.append(entry)
        common.row("roofline_rhs", name, f"{flops:.3e}",
                   f"{bytes_unfused:.3e}", f"{bytes_fused:.3e}",
                   f"{entry['ai_unfused']:.1f}" if entry["ai_unfused"]
                   else "", f"{entry['ai_fused']:.1f}"
                   if entry["ai_fused"] else "")
    common.save_json("roofline_rhs.json", {"entries": entries})
    return {"n_rhs_entries": len(entries)}


def run(quick: bool = True) -> dict:
    out = rhs_kernel_entry(quick=quick)
    if not os.path.isdir(DRYRUN_DIR) or not os.listdir(DRYRUN_DIR):
        print("no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all --mesh both` first")
        return out
    rows = print_table("single")
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
        common.row("# hillclimb-candidates",
                   f"worst_fraction={worst['arch']}/{worst['shape']}",
                   f"most_collective={coll['arch']}/{coll['shape']}")
    return {**out, "n_cells": len(rows)}


if __name__ == "__main__":
    run()

"""Roofline table: reads the dry-run artifacts (launch/dryrun.py) and prints
the per-(arch x shape) compute/memory/collective terms — the §Roofline
source of EXPERIMENTS.md.  Run the dry-run first:

    python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

from . import common

DRYRUN_DIR = os.path.join(common.ARTIFACTS, "dryrun")


def load(mesh: str = "single", tag: str = "") -> list[dict]:
    from repro.configs.shapes import SHAPES
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"{mesh}_*.json"))):
        base = os.path.basename(path)
        untagged = any(base.endswith(f"_{s}.json") for s in SHAPES)
        if tag and not base.endswith(f"_{tag}.json"):
            continue
        if not tag and not untagged:
            continue
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def print_table(mesh: str = "single", tag: str = "") -> list[dict]:
    rows = load(mesh, tag)
    common.row("# roofline", "arch", "shape", "status", "bound",
               "compute_s", "memory_s", "memory_raw_s", "collective_s",
               "roofline_frac", "useful_flop_ratio")
    for r in rows:
        if r["status"] != "ok":
            common.row("roofline", r["arch"], r["shape"], r["status"],
                       r.get("reason", r.get("error", ""))[:60], "", "", "",
                       "", "", "")
            continue
        t = r["roofline"]
        ratio = r.get("useful_flop_ratio")
        common.row("roofline", r["arch"], r["shape"], "ok", t["bound"],
                   f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
                   f"{t.get('memory_raw_s', t['memory_s']):.4f}",
                   f"{t['collective_s']:.4f}",
                   f"{t['roofline_fraction']:.3f}",
                   f"{ratio:.2f}" if ratio else "")
    return rows


def run(quick: bool = True) -> dict:
    if not os.path.isdir(DRYRUN_DIR) or not os.listdir(DRYRUN_DIR):
        print("no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all --mesh both` first")
        return {}
    rows = print_table("single")
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
        common.row("# hillclimb-candidates",
                   f"worst_fraction={worst['arch']}/{worst['shape']}",
                   f"most_collective={coll['arch']}/{coll['shape']}")
    return {"n_cells": len(rows)}


if __name__ == "__main__":
    run()

"""Paper Fig. 4 — strong scaling: MPI ranks per FLEXI environment.

On the TPU mapping, "ranks per environment" = element-space shards of one
environment over the `model` mesh axis; FLEXI's MPI halo exchange lowers to
`collective-permute` between neighboring shards (DESIGN.md §4).  Without
real multi-chip hardware we reproduce the paper's analysis structurally:

  (a) measured: solver wall time per RL step vs elements-per-environment on
      the host device (the per-rank load axis of Fig. 4 — the paper's
      "optimal load per core" knee is a per-device property);
  (b) compiled: lower one environment with its element grid sharded over
      model in {1, 2, 4, 8, 16} shards and report the collective-permute
      traffic per step from the compiled HLO — the halo-exchange cost that
      bounds strong scaling.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.cfd import initial, solver
from repro.launch import hlo_analysis

from . import common


def measured_load_sweep(quick: bool = True) -> list[dict]:
    common.row("# fig4_strong_scaling_measured", "n_elem", "dof",
               "t_rl_step_s", "t_per_dof_us")
    out = []
    for n_elem in (2, 3) if quick else (2, 3, 4):
        cfg = dataclasses.replace(
            dataclasses.replace(initial.HITConfig(), n_poly=3, k_peak=2.0,
                                k_eta=8.0),
            n_elem=n_elem)
        u0 = initial.sample_initial_state(jax.random.PRNGKey(0), cfg)
        cs = 0.1 * jnp.ones((cfg.n_elem,) * 3, jnp.float32)
        fn = jax.jit(lambda u, c: solver.advance_rl_interval(u, c, cfg))
        t = common.timeit(fn, u0, cs, warmup=1, iters=2)
        dof = (cfg.n_elem * (cfg.n_poly + 1)) ** 3
        out.append({"n_elem": n_elem, "dof": dof, "t_rl_step_s": t})
        common.row("fig4a", n_elem, dof, f"{t:.3f}", f"{t/dof*1e6:.2f}")
    return out


def compiled_halo_traffic() -> list[dict]:
    """Analytic halo-exchange volume per RL interval, cross-checked against
    the collective-permute ops XLA inserts in the (single-pod) dry-run of
    the sharded fleet (see benchmarks/roofline.py artifacts)."""
    cfg = initial.HITConfig()
    n = cfg.n_poly + 1
    k = cfg.n_elem
    rows = []
    common.row("# fig4b_halo_traffic", "shards", "halo_MB_per_rl_step",
               "compute_elems_per_shard")
    for shards in (1, 2, 4, 8):
        if k % shards:
            continue
        # slab decomposition along x: each shard owns k/shards element
        # layers; one face layer = k^2 elems * n^2 nodes * 5 channels,
        # exchanged both directions, x (advective + viscous) x 5 RK stages.
        face_floats = (k * k) * (n * n) * 5
        per_stage = 2 * 2 * face_floats * 4  # both dirs, adv+visc, f32 bytes
        per_rl = per_stage * 5 * cfg.n_substeps
        halo = 0.0 if shards == 1 else per_rl
        rows.append({"shards": shards, "halo_bytes_per_rl": halo,
                     "elems_per_shard": k**3 // shards})
        common.row("fig4b", shards, f"{halo/1e6:.2f}", k**3 // shards)
    return rows


def run(quick: bool = True) -> dict:
    a = measured_load_sweep(quick)
    b = compiled_halo_traffic()
    common.save_json("fig4_strong_scaling.json", {"measured": a, "halo": b})
    return {"measured": a, "halo": b}


if __name__ == "__main__":
    run(quick=True)

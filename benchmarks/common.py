"""Shared benchmark plumbing: timing, CSV output, artifact paths."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def save_json(name: str, payload) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def row(*cols) -> None:
    print(",".join(str(c) for c in cols), flush=True)

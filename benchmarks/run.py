"""Benchmark harness: one module per paper table/figure.

    python -m benchmarks.run [--full] [--only fig3,fig4,fig5,launch,roofline]

Outputs CSV-ish rows (grep-able by figure tag) and JSON artifacts under
benchmarks/artifacts/.  The roofline section reads the dry-run artifacts —
run `python -m repro.launch.dryrun --all --mesh both` first for the full
table (skipped gracefully otherwise).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger sweeps (slower)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: fig3,fig4,fig5,channel,"
                         "channel_p,launch,roofline,perf,fleet")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (fleet_scaling, launch_overhead, perf_compare, roofline,
                   scaling_strong, scaling_weak, training_curves)

    sections = [
        ("fig3", "weak scaling (paper Fig. 3)", scaling_weak.run),
        ("fig4", "strong scaling (paper Fig. 4)", scaling_strong.run),
        ("fig5", "training + baselines (paper Fig. 5 / Table 1)",
         training_curves.run),
        ("channel", "channel WMLES training + wall-model baselines",
         training_curves.run_channel),
        ("channel_p", "channel WMLES (velocity + wall-pressure obs) training",
         training_curves.run_channel_p),
        ("launch", "launch overhead (paper Sec. 3.3)", launch_overhead.run),
        ("roofline", "roofline table (dry-run artifacts)", roofline.run),
        ("perf", "perf hillclimb comparisons (EXPERIMENTS.md §Perf)",
         perf_compare.run),
        ("fleet", "heterogeneous fleet: broker throughput + pipeline overlap",
         fleet_scaling.run),
    ]
    for tag, title, fn in sections:
        if only and tag not in only:
            continue
        print(f"\n=== {title} ===", flush=True)
        t0 = time.perf_counter()
        fn(quick=quick)
        print(f"--- {tag} done in {time.perf_counter()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

"""Render the §Roofline markdown table for EXPERIMENTS.md from the dry-run
artifacts and splice it in between the <!-- ROOFLINE_TABLE --> marker and
the §Perf header.

    PYTHONPATH=src python -m benchmarks.make_roofline_md
"""
from __future__ import annotations

import json
import os

from . import common, roofline

MARKER = "<!-- ROOFLINE_TABLE -->"
EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def fmt(x, digits=4):
    return f"{x:.{digits}f}"


def render(mesh: str = "single") -> str:
    rows = roofline.load(mesh)
    out = [
        f"Single-pod mesh (data=16, model=16), 256 chips; terms in seconds "
        f"per step (calibrated per-device quantities — see Accounting "
        f"notes).  `frac` = compute_s / max(term); `ufr` = MODEL_FLOPS / "
        f"HLO_FLOPs.",
        "",
        "| arch | shape | bound | compute_s | memory_s | memory_raw_s | "
        "collective_s | frac | ufr | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | | | "
                       f"{r['reason']} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | "
                       f"{r.get('error','')[:60]} |")
            continue
        t = r["roofline"]
        ufr = r.get("useful_flop_ratio")
        # one sentence on what moves the dominant term down
        note = {
            "compute": "at roofline; next lever = fewer remat recomputes",
            "memory": "fuse/bf16 the dominant buffers; shrink temps",
            "collective": "re-layout: cut all-gathers (see §Perf)",
        }[t["bound"]]
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['bound']} | "
            f"{fmt(t['compute_s'])} | {fmt(t['memory_s'])} | "
            f"{fmt(t['memory_raw_s'], 2)} | {fmt(t['collective_s'])} | "
            f"{t['roofline_fraction']:.3f} | "
            f"{f'{ufr:.2f}' if ufr else ''} | {note} |")
    return "\n".join(out)


def render_rhs() -> str:
    """Markdown table for the fused DGSEM-RHS arithmetic-intensity entry
    (benchmarks/roofline.py rhs_kernel_entry -> roofline_rhs.json).
    Returns "" when the artifact has not been produced yet."""
    path = os.path.join(common.ARTIFACTS, "roofline_rhs.json")
    if not os.path.exists(path):
        return ""
    with open(path) as f:
        entries = json.load(f)["entries"]
    out = [
        "Fused DGSEM-RHS mega-kernel: XLA-counted flops per evaluation; "
        "`ai_fused` assumes HBM traffic of state-in + cs-in + rhs-out only "
        "(all intermediates in VMEM), vs XLA's bytes-accessed for the "
        "unfused assembly.",
        "",
        "| case | flops | bytes_unfused | bytes_fused_ideal | ai_unfused | "
        "ai_fused |",
        "|---|---|---|---|---|---|",
    ]
    for e in entries:
        out.append(
            f"| {e['case']} | {e['flops']:.3e} | {e['bytes_unfused']:.3e} | "
            f"{e['bytes_fused_ideal']:.3e} | "
            f"{e['ai_unfused']:.1f} | {e['ai_fused']:.1f} |")
    return "\n".join(out)


def splice() -> None:
    table = render()
    rhs_table = render_rhs()
    if rhs_table:
        table = table + "\n\n" + rhs_table
    if not os.path.exists(EXPERIMENTS):
        # nothing to splice into — print the rendered tables instead so the
        # command is still useful in a fresh checkout
        print(f"{EXPERIMENTS} not found; rendered tables:\n")
        print(table)
        return
    with open(EXPERIMENTS) as f:
        text = f.read()
    head, _, rest = text.partition(MARKER)
    tail_idx = rest.find("\n## §Perf")
    tail = rest[tail_idx:] if tail_idx >= 0 else rest
    with open(EXPERIMENTS, "w") as f:
        f.write(head + MARKER + "\n\n" + table + "\n" + tail)
    print(f"spliced {len(table.splitlines())} table lines into EXPERIMENTS.md")


if __name__ == "__main__":
    splice()
